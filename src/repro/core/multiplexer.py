"""Global Controller (paper §III-D, Fig. 3) + cross-job budget arbitration.

Owns the job registry for a device: launches each job's Executor on its own
thread, funnels measured operator latencies back to the Memory Scheduler,
triggers re-planning when latencies drift past the update threshold
(§IV-E), and distributes fresh plans — applied by each Executor at its next
iteration boundary, exactly as the paper specifies ("the system will apply
the new plan right before computing the next batch of data").

The four-step scheduling procedure of §III-D maps to:
  1. `launch()`      — collect the new job's graph + cold-start latencies
                       (CostModel / LatencyMLP prediction, no passive mode)
  2. `_replan()`     — Memory Scheduler generates/updates the plans
  3. Executor threads + the shared AsyncSwapExecutor run the plans
  4. latency reports — EWMA-folded; drift beyond threshold triggers 2.

Beyond the paper: the **BudgetArbiter** owns the device-wide byte budget
and splits it across live jobs by a pluggable policy (equal-share,
priority-weighted, peak-proportional from measured per-job peaks).  The
split is recomputed at every launch, every finish (the departing job's
bytes are reclaimed and redistributed — skipped when the departing job
held zero bytes of the split), and every latency-drift replan; per-job
pipelines then plan against the arbiter-assigned slice instead of the
full device (passes.PriorityPass / passes.BudgetAutoscalePass).

Plan versions swap at iteration boundaries by default, so a budget move
never tears an in-flight iteration.  In arbiter mode ``"preempt"`` a
SHRUNKEN slice additionally takes effect mid-iteration: the controller
builds an incremental remainder plan (``MemoryScheduler.replan_from``)
and hot-swaps it into the victim's running executor at its next *safe
point* (``engine.find_safe_points`` — no transfer in flight, residency
at a local minimum), closing the across-iteration lag a bursty arrival
otherwise suffers.  See docs/architecture.md, "Safe points and plan
hot-swap".
"""
from __future__ import annotations

import dataclasses
import threading
import time as _time
import traceback
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .access import AccessSequence
from .cost_model import CostModel, EWMATracker
from .engine import (DeviceLedger, DmaChannel, JobLedgerView, MemoryEngine,
                     find_safe_points)
from .executor import JaxprExecutor
from .experience import ExperienceStore, device_identity
from .graph_capture import capture_train_step
from .peak_analysis import analyze
from .plan import MachineProfile, SchedulingPlan
from .scheduler import MemoryScheduler, SchedulerConfig
from .telemetry import TelemetryHub


class JobFailedError(RuntimeError):
    """One or more job threads died.  Carries every failed handle so a
    multi-job failure is reported whole instead of masking all but the
    first; the first underlying exception is chained as ``__cause__``."""

    def __init__(self, failures: Dict[str, BaseException],
                 tracebacks: Optional[Dict[str, str]] = None):
        self.failures = dict(failures)
        self.tracebacks = dict(tracebacks or {})
        detail = "; ".join(
            f"{j}: {type(e).__name__}: {e}" for j, e in self.failures.items())
        super().__init__(
            f"{len(self.failures)} job(s) failed — {detail}")


@dataclasses.dataclass
class JobHandle:
    job_id: str
    seq: AccessSequence
    closed_jaxpr: Any
    args: tuple
    iterations: int
    priority: float = 1.0
    thread: Optional[threading.Thread] = None
    plan: Optional[SchedulingPlan] = None
    plan_version: int = 0
    done: bool = False
    error: Optional[BaseException] = None
    error_tb: Optional[str] = None
    stats: List[Any] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)
    peak_bytes: int = 0
    # the arbiter-assigned slice of the device budget, as a live view over
    # the shared DeviceLedger (None until the first split)
    ledger_view: Optional[JobLedgerView] = None
    # structural fingerprint in the attached ExperienceStore (None when
    # the controller runs without one)
    fingerprint: Optional[str] = None
    # the executor currently running this job's iteration (None between
    # iterations / after finish) — the preemptive arbiter hot-swaps plans
    # into it at a safe point
    executor: Optional[Any] = None
    # (plan_version, safe_op) of every preemptive hot-swap requested
    preemptions: List[Any] = dataclasses.field(default_factory=list)
    # the JobSpec this handle was submitted with (None only for handles
    # built outside the submit() path)
    spec: Optional[Any] = None
    # admission-time predicted peak (captured only when a DriftMonitor is
    # attached — the measured peak is compared against it on exit)
    predicted_peak: Optional[int] = None

    @property
    def budget_bytes(self) -> Optional[int]:
        return self.ledger_view.budget_bytes if self.ledger_view else None


def _is_serve(handle: "JobHandle") -> bool:
    """Serve handles are discriminated by their spec's ``kind``, NOT by
    ``closed_jaxpr is None`` — handles built outside submit() (tests,
    manual registration) legitimately carry no jaxpr but are training
    jobs as far as the iteration-DAG scheduler is concerned."""
    return (handle.spec is not None
            and getattr(handle.spec, "kind", "train") == "serve")


@dataclasses.dataclass
class CapturedJob:
    """A JobSpec resolved and captured: everything admission + submit need.

    Produced by ``GlobalController.capture_spec`` so the service daemon can
    predict a job's peak (``predict_peak``) *before* committing to
    ``submit`` — capture once, admit, then run from the same capture."""

    seq: AccessSequence
    closed_jaxpr: Any
    args: Tuple[Any, Any, Any]
    fingerprint: Optional[str] = None


# ----------------------------------------------------------------------
# Budget arbitration (device-wide budget -> per-job slices)
# ----------------------------------------------------------------------
def _equal_weights(arb: "BudgetArbiter", live: Sequence[str]
                   ) -> Dict[str, float]:
    return {j: 1.0 for j in live}


def _priority_weights(arb: "BudgetArbiter", live: Sequence[str]
                      ) -> Dict[str, float]:
    return {j: max(arb.priorities.get(j, 1.0), 1e-9) for j in live}


def _peak_weights(arb: "BudgetArbiter", live: Sequence[str]
                  ) -> Dict[str, float]:
    """Proportional to each job's peak demand: the measured per-job peak
    (folded in from the shared DeviceLedger / EngineTrace as the job runs)
    once available, else a persisted peak a PRIOR run measured for the
    same fingerprint (experience prior), else the predicted vanilla peak
    from capture."""
    out: Dict[str, float] = {}
    for j in live:
        w = arb.demands.get(j, 0)
        prior = arb.priors.get(j)
        if prior is not None and prior.peak_bytes \
                and j not in arb.live_peak_seen:
            w = prior.peak_bytes
        out[j] = float(max(w, 1))
    return out


# how strongly a job's measured stall share bids for extra bytes under
# the eor-learned policy: weight = 1 + GAIN * stall_share (stall_share in
# [0, 1], so weights stay within [1, 1+GAIN] — bounded re-splits)
EOR_LEARNED_GAIN = 3.0


def _eor_learned_weights(arb: "BudgetArbiter", live: Sequence[str]
                         ) -> Dict[str, float]:
    """Learned from the measured-telemetry plane: a job losing more of
    its measured time to memory stalls (passive swap-ins, late
    prefetches) is the job whose slice is too small — it bids for more
    bytes in proportion to its measured stall share.  Jobs with no live
    samples yet bid the stall share a PRIOR run persisted for the same
    fingerprint (experience prior) when one exists, else the neutral
    weight — so the policy degrades to equal-share only on a genuinely
    first-ever run."""
    hub = arb.telemetry
    out: Dict[str, float] = {}
    for j in live:
        share = None
        if hub is not None and hub.has_samples(j):
            share = hub.stall_share(j)
        if share is None:
            prior = arb.priors.get(j)
            share = prior.stall_share if prior is not None else 0.0
        out[j] = 1.0 + EOR_LEARNED_GAIN * share
    return out


ARBITER_POLICIES: Dict[str, Callable[["BudgetArbiter", Sequence[str]],
                                     Dict[str, float]]] = {
    "equal": _equal_weights,
    "priority": _priority_weights,
    "peak": _peak_weights,
    "eor-learned": _eor_learned_weights,
}


ARBITER_MODES = ("boundary", "preempt")


class BudgetArbiter:
    """Owns the device-wide byte budget and splits it across live jobs.

    ``split(live)`` runs weighted water-filling: each job's raw share is
    ``capacity * w_j / Σw``; a job whose known demand (its vanilla peak —
    it can never profitably hold more) is below its share is capped at the
    demand and the surplus re-flows to the uncapped jobs.  Policies are
    pluggable via ``ARBITER_POLICIES`` (equal / priority / peak).  Every
    split is appended to ``history`` so tests and reports can audit how
    budgets moved across launch/finish/drift replans.

    ``mode`` decides how a *shrunken* slice takes effect on a running job:
    ``"boundary"`` (default, the paper's rule) waits for the victim's next
    iteration boundary; ``"preempt"`` additionally hot-swaps an incremental
    remainder plan in at the victim's next safe point, shrinking it
    mid-iteration (``GlobalController._preempt_victims``).
    """

    def __init__(self, capacity_bytes: int, policy: str = "equal",
                 mode: str = "boundary",
                 telemetry: Optional[TelemetryHub] = None):
        if policy not in ARBITER_POLICIES:
            raise KeyError(f"unknown arbiter policy {policy!r}; "
                           f"known: {sorted(ARBITER_POLICIES)}")
        if mode not in ARBITER_MODES:
            raise KeyError(f"unknown arbiter mode {mode!r}; "
                           f"known: {list(ARBITER_MODES)}")
        self.capacity = int(capacity_bytes)
        self.policy = policy
        self.mode = mode
        # measured-telemetry plane: the eor-learned policy reads each
        # job's measured stall share from here (None -> equal weights)
        self.telemetry = telemetry
        self.priorities: Dict[str, float] = {}
        self.demands: Dict[str, int] = {}       # peak demand, bytes
        # experience priors: persisted telemetry summaries standing in
        # for live measurements on jobs that have not produced any yet
        # (set_prior; consumed by the eor-learned and peak policies)
        self.priors: Dict[str, Any] = {}
        # jobs whose demand has been updated from a LIVE measured peak —
        # from then on the prior stops overriding the peak policy
        self.live_peak_seen: Dict[str, bool] = {}
        self.history: List[Dict[str, int]] = []
        self.last_assignment: Dict[str, int] = {}

    # -- victim selection ----------------------------------------------
    def victims(self, new_assignment: Dict[str, int],
                prev_assignment: Dict[str, int],
                usage: Dict[str, int]) -> List[str]:
        """Jobs whose slice shrank under the new split and whose usage
        exceeds the new slice — the jobs preemption must act on, largest
        over-share first.  ``usage`` should be the job's *expected*
        footprint under its running plan (the controller passes
        max(live bytes, measured peak)): a victim below its new slice at
        the split instant but heading over it later in the iteration
        still needs the mid-iteration shrink."""
        out = [j for j, b in new_assignment.items()
               if j in prev_assignment and b < prev_assignment[j]
               and usage.get(j, 0) > b]
        out.sort(key=lambda j: new_assignment[j] - usage.get(j, 0))
        return out

    # -- registry ------------------------------------------------------
    def register(self, job_id: str, priority: float = 1.0,
                 demand_bytes: int = 0) -> None:
        self.priorities[job_id] = priority
        self.demands[job_id] = int(demand_bytes)

    def update_demand(self, job_id: str, demand_bytes: int) -> None:
        """Fold in a measured peak (monotone max — demand never shrinks
        within a job's lifetime)."""
        if job_id in self.demands:
            self.demands[job_id] = max(self.demands[job_id],
                                       int(demand_bytes))
            self.live_peak_seen[job_id] = True

    def set_prior(self, job_id: str, prior) -> None:
        """Attach a persisted experience prior (a TelemetrySummary-shaped
        object with ``stall_share`` and ``peak_bytes``) for a job that
        has not produced live samples yet — the eor-learned and peak
        policies read it until live telemetry supersedes it."""
        if prior is not None:
            self.priors[job_id] = prior

    def unregister(self, job_id: str) -> None:
        self.priorities.pop(job_id, None)
        self.demands.pop(job_id, None)
        self.priors.pop(job_id, None)
        self.live_peak_seen.pop(job_id, None)

    # -- the split -----------------------------------------------------
    def split(self, live: Sequence[str]) -> Dict[str, int]:
        live = [j for j in live if j in self.priorities]
        if not live:
            self.last_assignment = {}
            return {}
        weights = ARBITER_POLICIES[self.policy](self, live)
        assignment: Dict[str, int] = {}
        remaining = self.capacity
        pool = sorted(live)
        # water-fill: repeatedly give each job its weighted share of what
        # is left; jobs capped by demand leave the pool and their surplus
        # re-flows (bounded by len(live) rounds)
        while pool and remaining > 0:
            total_w = sum(weights[j] for j in pool)
            capped = []
            for j in pool:
                share = int(remaining * weights[j] / total_w)
                demand = self.demands.get(j, 0)
                if demand and demand < share:
                    assignment[j] = demand
                    capped.append(j)
            if not capped:
                for j in pool:
                    assignment[j] = int(remaining * weights[j] / total_w)
                break
            remaining -= sum(assignment[j] for j in capped)
            pool = [j for j in pool if j not in capped]
        for j in live:
            assignment.setdefault(j, 0)
        self.last_assignment = dict(assignment)
        self.history.append(dict(assignment))
        return assignment


class GlobalController:
    def __init__(self, profile: Optional[MachineProfile] = None,
                 scheduler_config: Optional[SchedulerConfig] = None,
                 cost_model: Optional[CostModel] = None,
                 device_capacity: Optional[int] = None,
                 async_swap: bool = True,
                 pipeline_name: Optional[str] = None,
                 arbiter: Optional[BudgetArbiter] = None,
                 arbiter_policy: Optional[str] = None,
                 arbiter_mode: Optional[str] = None,
                 telemetry: Optional[TelemetryHub] = None,
                 safe_point_source: str = "measured",
                 experience: Optional[ExperienceStore] = None,
                 experience_dir: Optional[str] = None,
                 events=None, drift=None):
        self.profile = profile or MachineProfile()
        # structured event stream (observability plane): failure paths
        # that must never take a job down with them — experience
        # flushes, survivor replans, preempt replans — emit WARN events
        # here IN ADDITION to their recoverable-failure lists, so a
        # silent list append becomes a visible, timestamped signal.
        # Always present (a bounded ring buffer costs nothing idle).
        if events is None:
            from ..obs.events import EventLog
            events = EventLog()
        self.events = events
        # optional sim-vs-measured drift monitor: when attached, submit
        # captures the predicted peak and _on_job_exit feeds it the
        # measured one.  None (the default) adds zero work per job.
        self.drift = drift
        # ONE measured-telemetry hub per device: every executor produces
        # into it; safe-point detection, drift replans, swap-window sizing
        # and the eor-learned arbiter policy consume from it
        self.telemetry = telemetry or TelemetryHub(clock="real")
        # the experience plane (cross-run persistence): an attached store
        # warm-boots the cost model's calibration, the pipeline's plan
        # cache, the planner's DMA bandwidth, and the arbiter's learned
        # priors — and distilled experience flushes back on job finish
        if experience is None and experience_dir is not None:
            experience = ExperienceStore(
                experience_dir, device_id=device_identity(self.profile))
        self.experience = experience
        # (job_id, error) for experience flushes that failed — persistence
        # must never take a job down with it
        self.experience_failures: List[tuple] = []
        # how `_preempt_victims` finds splice points: "measured" detects
        # them from the hub's residency records (falling back to modeled
        # below min_iterations of samples — §IV-C blending), "modeled"
        # always uses the plan's DeviceLedger model
        self.safe_point_source = safe_point_source
        pipeline = None
        if pipeline_name is not None:
            from .passes import build_pipeline
            cfg = scheduler_config or SchedulerConfig()
            scheduler_config = cfg
            pipeline = build_pipeline(pipeline_name, profile=self.profile,
                                      config=cfg)
        self.scheduler = MemoryScheduler(self.profile, scheduler_config,
                                         pipeline=pipeline,
                                         experience=self.experience)
        if self.scheduler.pipeline.telemetry is None:
            self.scheduler.pipeline.telemetry = self.telemetry
        # cost model warm boot: with a store attached, capture-time
        # latency estimates start from the calibration a prior run
        # persisted instead of probe constants (and keep recalibrating
        # online from the hub — see report_telemetry)
        self.cost_model = cost_model or CostModel(experience=self.experience)
        # one engine ledger + DMA channel shared by every job on the device
        self.engine = MemoryEngine(self.profile,
                                   capacity_bytes=device_capacity,
                                   telemetry=self.telemetry)
        self.accountant: DeviceLedger = self.engine.ledger
        self.channel: DmaChannel = self.engine.channel
        # the device-wide budget the arbiter splits: explicit capacity,
        # else the scheduler's budget, else the device size
        cap = device_capacity
        if cap is None:
            cap = (self.scheduler.config.memory_budget_bytes
                   or self.profile.device_memory_bytes)
        mode = arbiter_mode or self.scheduler.config.arbiter_mode
        self.arbiter = arbiter or (
            BudgetArbiter(cap, policy=arbiter_policy, mode=mode,
                          telemetry=self.telemetry)
            if arbiter_policy is not None else None)
        if self.arbiter is not None and self.arbiter.telemetry is None:
            self.arbiter.telemetry = self.telemetry
        self.async_swap = async_swap
        self.jobs: Dict[str, JobHandle] = {}
        self.ewma: Dict[str, EWMATracker] = {}
        self._lock = threading.Lock()
        self._replan_count = 0
        self._preempt_count = 0
        # replans that failed while redistributing a departed job's budget
        # (survivors keep their current plans): (departed_job_id, error)
        self.replan_failures: List[tuple] = []
        # incremental replans that failed while preempting a victim (the
        # victim keeps its plan until the boundary): (job_id, error)
        self.preempt_failures: List[tuple] = []

    # ------------------------------------------------------------------
    def capture_spec(self, spec) -> CapturedJob:
        """Admission hook #1: resolve a ``JobSpec`` and capture its graph.

        Resolution goes through ``repro.service.workloads`` (in-process
        ``spec.payload`` wins; otherwise the registered / importable
        workload factory named by ``spec.workload``).  The capture is
        reusable: the daemon captures once, predicts the peak, and hands
        the same ``CapturedJob`` to ``submit`` after admission.

        Serve specs (``kind="serve"``) have no jaxpr to capture — their
        timeline is request-driven, not an iteration DAG.  They capture to
        a *synthetic* access sequence whose tensors are the per-slot KV
        footprints, so ``predict_peak`` and the arbiter's demand math see
        a serving job through the same lens as a training one."""
        if getattr(spec, "kind", "train") == "serve":
            return self._capture_serve_spec(spec)
        from ..service.workloads import resolve_workload
        step_fn, params, opt_state, batch = resolve_workload(spec)
        # reflect current device contention into cold-start predictions
        self.cost_model.utilization = min(
            0.9, 0.3 * sum(1 for j in self.jobs.values() if not j.done))
        seq, closed = capture_train_step(
            step_fn, params, opt_state, batch, job_id=spec.job_id,
            cost_model=self.cost_model)
        fp = spec.fingerprint
        if self.experience is not None:
            try:
                fp = self.experience.fingerprint(seq)
            except Exception as e:  # noqa: BLE001 - cold boot instead
                self.experience_failures.append((spec.job_id, e))
                self.events.warn("experience",
                                 "fingerprint computation failed; "
                                 "job cold-boots",
                                 job_id=spec.job_id, error=repr(e))
        return CapturedJob(seq=seq, closed_jaxpr=closed,
                           args=(params, opt_state, batch), fingerprint=fp)

    # ------------------------------------------------------------------
    def _capture_serve_spec(self, spec) -> CapturedJob:
        """Resolve a serve spec to ``(serving_engine, requests)`` and build
        the synthetic access sequence standing in for its jaxpr: one
        decode-turn operator touching a full-cache tensor per batch slot.
        ``analyze(..., free_at_last_use=False)`` over it is exactly the
        all-slots-resident KV bound admission should reserve against."""
        from ..service.workloads import resolve_serve_workload
        from .access import Operator, TensorSpec, TensorKind
        engine, requests = resolve_serve_workload(spec)
        sp = spec.serve
        per_seq = engine.bytes_per_token * (sp.prompt_len + sp.gen_len)
        tensors = {
            f"kvslot{i}": TensorSpec(
                tid=f"kvslot{i}", size_bytes=per_seq,
                kind=TensorKind.ACTIVATION, job_id=spec.job_id)
            for i in range(sp.max_sequences)}
        ops = [Operator(idx=0, name="decode_turn", inputs=tuple(tensors),
                        outputs=tuple(tensors), latency=1e-3,
                        job_id=spec.job_id)]
        seq = AccessSequence(spec.job_id, ops, tensors, initial_resident=[])
        return CapturedJob(seq=seq, closed_jaxpr=None,
                           args=(engine, requests), fingerprint=None)

    # ------------------------------------------------------------------
    def predict_peak(self, seq: AccessSequence,
                     budget_hint_bytes: Optional[int] = None
                     ) -> Tuple[int, str]:
        """Admission hook #2: predicted peak bytes for a captured job,
        with its provenance (``"experience"`` or ``"cost-model"``).

        A warm fingerprint returns the measured peak a prior run distilled
        into the ``ExperienceStore``.  Unknown fingerprints get the
        conservative no-free bound from the analyzer (every tensor held to
        its last use), optionally raised to the caller's budget hint — an
        upper bound the admission queue refines from the first profiled
        iteration's measured peak."""
        if self.experience is not None:
            try:
                prior = self.experience.predicted_peak(seq)
                if prior is not None:
                    return prior
            except Exception as e:  # noqa: BLE001 - fall through to model
                self.events.warn("experience",
                                 "predicted-peak prior lookup failed; "
                                 "using cost-model bound",
                                 job_id=seq.job_id, error=repr(e))
        bound = int(analyze([seq], free_at_last_use=False).peak_bytes)
        if budget_hint_bytes:
            bound = max(bound, int(budget_hint_bytes))
        return bound, "cost-model"

    # ------------------------------------------------------------------
    def submit(self, spec, captured: Optional[CapturedJob] = None
               ) -> JobHandle:
        """Register + start a job from a ``JobSpec`` (async, like the
        paper's sub-process per Executor).  The single submission entry
        point shared by in-process callers, the scheduler daemon, and the
        benchmark suite.  ``spec.priority`` feeds the BudgetArbiter's
        priority-weighted policy and PriorityPass victim ordering; when
        None, a priority configured in SchedulerConfig.job_priorities
        (else 1.0) applies.  Pass ``captured`` to reuse a
        ``capture_spec`` result (the daemon captures before admission)."""
        if captured is None:
            captured = self.capture_spec(spec)
        if getattr(spec, "kind", "train") == "serve":
            return self._submit_serve(spec, captured)
        seq, closed = captured.seq, captured.closed_jaxpr
        with self._lock:
            if spec.job_id in self.jobs and not self.jobs[spec.job_id].done:
                raise ValueError(f"job {spec.job_id!r} is already live")
            self.scheduler.register_job(seq, priority=spec.priority)
            eff_priority = self.scheduler.priority_of(spec.job_id)
            handle = JobHandle(job_id=spec.job_id, seq=seq,
                               closed_jaxpr=closed, args=captured.args,
                               iterations=spec.iterations,
                               priority=eff_priority, spec=spec,
                               fingerprint=captured.fingerprint)
            self.jobs[spec.job_id] = handle
            self.ewma[spec.job_id] = EWMATracker(
                alpha=self.scheduler.config.ewma_alpha)
            if self.arbiter is not None:
                # peak demand: predicted vanilla peak until measurements land
                demand = analyze([seq], free_at_last_use=False).peak_bytes
                self.arbiter.register(spec.job_id, priority=eff_priority,
                                      demand_bytes=demand)
            if self.experience is not None:
                # experience priors: a prior run's distilled telemetry
                # for this fingerprint stands in for live samples the
                # job has not produced yet (eor-learned / peak policies)
                try:
                    prior = self.experience.prior(seq)
                    if prior is not None and self.arbiter is not None:
                        self.arbiter.set_prior(spec.job_id, prior)
                except Exception as e:  # noqa: BLE001 - cold boot instead
                    self.experience_failures.append((spec.job_id, e))
                    self.events.warn("experience",
                                     "arbiter prior lookup failed; "
                                     "job starts with live samples only",
                                     job_id=spec.job_id, error=repr(e))
            if self.drift is not None:
                # admission-time prediction pinned for the exit-time
                # comparison (skipped entirely without a monitor)
                try:
                    handle.predicted_peak, _src = self.predict_peak(seq)
                except Exception:  # noqa: BLE001 - drift is best-effort
                    handle.predicted_peak = None
            if spec.schedule:
                self._replan()
        t = threading.Thread(target=self._run_job, args=(handle,), daemon=True)
        handle.thread = t
        t.start()
        return handle

    # ------------------------------------------------------------------
    def _submit_serve(self, spec, captured: CapturedJob) -> JobHandle:
        """Register + start a serving job.  It shares the device ledger,
        DMA channel and arbiter slice with every training job, but its
        residency is planned per decode turn by the serving plane's
        ``KvResidencyPass`` — the iteration-DAG MemoryScheduler never sees
        it (its timeline is a rolling horizon, not a fixed op sequence)."""
        with self._lock:
            if spec.job_id in self.jobs and not self.jobs[spec.job_id].done:
                raise ValueError(f"job {spec.job_id!r} is already live")
            handle = JobHandle(job_id=spec.job_id, seq=captured.seq,
                               closed_jaxpr=None, args=captured.args,
                               iterations=spec.iterations,
                               priority=spec.priority or 1.0, spec=spec)
            self.jobs[spec.job_id] = handle
            if self.arbiter is not None:
                demand = analyze([captured.seq],
                                 free_at_last_use=False).peak_bytes
                self.arbiter.register(spec.job_id,
                                      priority=spec.priority or 1.0,
                                      demand_bytes=demand)
            if spec.schedule:
                self._replan()
        t = threading.Thread(target=self._run_serve_job, args=(handle,),
                             daemon=True)
        handle.thread = t
        t.start()
        return handle

    # ------------------------------------------------------------------
    def _run_serve_job(self, handle: JobHandle) -> None:
        """Thread body for a serving job: hand the request trace to the
        ServingEngine, which drives a ServeSession against OUR ledger and
        channel — KV blocks and training tensors contend for the same
        bytes and the same DMA slot, which is the whole point."""
        try:
            engine, requests = handle.args
            sp = handle.spec.serve
            report, _ = engine.serve(
                requests, budget_bytes=handle.budget_bytes,
                schedule=handle.spec.schedule,
                block_tokens=sp.block_tokens, engine=self.engine,
                job_id=handle.job_id)
            handle.stats.append(report)
            handle.step_times.append(report.total_time)
            handle.peak_bytes = max(handle.peak_bytes, report.peak_bytes)
        except BaseException as e:  # noqa: BLE001 - surfaced via wait()
            handle.error = e
            handle.error_tb = traceback.format_exc()
        finally:
            self._on_job_exit(handle)

    # ------------------------------------------------------------------
    def launch(self, step_fn: Callable, params, opt_state, batch,
               job_id: str, iterations: int = 3,
               schedule: bool = True,
               priority: Optional[float] = None) -> JobHandle:
        """Deprecated shim over :meth:`submit` — build a ``JobSpec`` with
        an in-process payload and submit it.  Kept one release for
        out-of-repo callers; everything in-repo uses ``submit``."""
        warnings.warn(
            "GlobalController.launch(step_fn, ...) is deprecated; build a "
            "repro.service.JobSpec and call GlobalController.submit(spec)",
            DeprecationWarning, stacklevel=2)
        from ..service.jobspec import JobSpec
        spec = JobSpec(job_id=job_id, iterations=iterations,
                       schedule=schedule, priority=priority,
                       payload=(step_fn, params, opt_state, batch))
        return self.submit(spec)

    # ------------------------------------------------------------------
    def _replan(self) -> None:
        """Memory Scheduler pass over all live jobs; distribute plans.

        With an arbiter, the device budget is re-split first (launch,
        finish, and latency drift all funnel through here, so "re-splits on
        every replan" is structural) and the per-job slices are planned
        against.  Executors pick the new plan up at their next iteration
        boundary — `_run_job` reads (plan, version) under the lock only
        between iterations, so a budget move never tears a running one."""
        live = [j for j, h in self.jobs.items() if not h.done]
        if not live:
            return
        budgets: Optional[Dict[str, int]] = None
        prev_assignment: Dict[str, int] = {}
        if self.arbiter is not None:
            for j in live:
                # fold measured peaks (shared-ledger accounting) into demand
                measured = self.accountant.job_peak(j)
                if measured:
                    self.arbiter.update_demand(j, measured)
            prev_assignment = dict(self.arbiter.last_assignment)
            budgets = self.arbiter.split(live)
        # serve jobs take part in the budget split but not in iteration-DAG
        # planning — their per-turn KvResidencyPass plans against the slice
        planned = [j for j in live if not _is_serve(self.jobs[j])]
        if planned:
            plan_budgets = None if budgets is None else {
                j: budgets[j] for j in planned if j in budgets}
            result = self.scheduler.schedule(planned, budgets=plan_budgets)
            for j in planned:
                h = self.jobs[j]
                h.plan = result.plans[j]
                h.plan_version += 1
        for j in live:
            if budgets is not None:
                self.jobs[j].ledger_view = self.accountant.view(
                    j, budgets.get(j))
        self._replan_count += 1
        if (self.arbiter is not None and self.arbiter.mode == "preempt"
                and budgets is not None):
            self._preempt_victims(budgets, prev_assignment)

    # ------------------------------------------------------------------
    def _preempt_victims(self, budgets: Dict[str, int],
                         prev_assignment: Dict[str, int]) -> None:
        """Preemptive arbitration (arbiter mode "preempt"): a launch/burst
        just shrank some live jobs' slices.  Instead of letting each victim
        finish its iteration over-share, build an incremental remainder
        plan (eager swap-outs from the victim's next safe point, via
        ``MemoryScheduler.replan_from``) and hot-swap it into the running
        executor at that safe point.  The boundary plan distributed by
        ``_replan`` still lands at the next iteration — preemption only
        closes the gap until then.  Every future safe point is eligible
        for the splice: if the executor already passed the one the
        remainder plan was built from, events triggered between it and
        the actual splice simply never fire — a bounded, graceful
        degradation (later eager swap-outs still apply, and the boundary
        plan completes the shrink).  Called under the controller lock."""
        # expected footprint under the running plan: live bytes now, or
        # the measured peak so far — a victim below its shrunken slice at
        # this instant can still be heading over it later in the iteration
        usage = {j: max(self.accountant.job_bytes(j),
                        self.accountant.job_peak(j)) for j in budgets}
        for j in self.arbiter.victims(budgets, prev_assignment, usage):
            h = self.jobs.get(j)
            ex = h.executor if h is not None else None
            if ex is None:
                continue            # between iterations: boundary covers it
            running = ex.plan
            safe = find_safe_points(h.seq, running,
                                    source=self.safe_point_source,
                                    telemetry=self.telemetry)
            cur = ex.current_op_index
            future = [sp.op_idx for sp in safe if sp.op_idx > cur]
            if not future:
                continue            # iteration nearly over: boundary covers it
            try:
                res = self.scheduler.replan_from(
                    j, running if running is not None
                    else SchedulingPlan(job_id=j),
                    future[0], budgets[j])
            except Exception as e:  # noqa: BLE001 - victim keeps its plan
                self.preempt_failures.append((j, e))
                self.events.warn("preempt",
                                 "incremental preempt replan failed; "
                                 "victim keeps its plan to the boundary",
                                 job_id=j, error=repr(e))
                continue
            prior_n = len(running.events) if running is not None else 0
            if len(res.plans[j].events) == prior_n:
                continue            # remainder already fits: splice is a no-op
            ex.request_plan(res.plans[j], future)
            h.preemptions.append((h.plan_version, future[0]))
            self._preempt_count += 1

    # ------------------------------------------------------------------
    def _run_job(self, handle: JobHandle) -> None:
        try:
            args = handle.args
            version_used = -1
            ex: Optional[JaxprExecutor] = None
            for it in range(handle.iterations):
                with self._lock:
                    plan = handle.plan
                    version = handle.plan_version
                if ex is None or version != version_used:
                    if ex is not None:
                        ex.close()
                    # carry the host store across plan versions
                    old_host = ex.host if ex is not None else {}
                    old_compressed = (set(ex.ctx.host_compressed)
                                      if ex is not None else set())
                    ex = JaxprExecutor(
                        handle.closed_jaxpr, handle.seq, plan,
                        accountant=self.accountant, channel=self.channel,
                        async_swap=self.async_swap, measure_latency=True,
                        telemetry=self.telemetry)
                    ex.host.update(old_host)
                    ex.ctx.host_compressed |= old_compressed
                    version_used = version
                    handle.executor = ex
                else:
                    # fresh per-iteration stores, persistent host cache
                    # (incl. which parked copies are quantized — fetching
                    # them must go through the dequantize path)
                    host = ex.host
                    compressed = set(ex.ctx.host_compressed)
                    ex = JaxprExecutor(
                        handle.closed_jaxpr, handle.seq, plan,
                        accountant=self.accountant, channel=self.channel,
                        async_swap=self.async_swap, measure_latency=True,
                        telemetry=self.telemetry)
                    ex.host.update(host)
                    ex.ctx.host_compressed |= compressed
                    handle.executor = ex
                t0 = _time.perf_counter()
                outs = ex.run(*args)
                handle.step_times.append(_time.perf_counter() - t0)
                handle.stats.append(ex.stats)
                handle.peak_bytes = max(handle.peak_bytes, ex.stats.peak_bytes)
                # feed params/opt-state back (outputs 0,1 by convention)
                n_p = len(__import__("jax").tree.flatten(args[0])[0])
                n_o = len(__import__("jax").tree.flatten(args[1])[0])
                import jax as _jax
                p = _jax.tree.unflatten(_jax.tree.structure(args[0]),
                                        outs[:n_p])
                o = _jax.tree.unflatten(_jax.tree.structure(args[1]),
                                        outs[n_p:n_p + n_o])
                args = (p, o, args[2])
                # measured-telemetry feedback (paper step 4): the hub
                # already holds this iteration's op samples; fold them
                # into the job's sequence and replan on HUB-reported
                # drift (the scheduler-private EWMA path stays available
                # as report_latencies for embedders without a hub)
                drift = self.report_telemetry(handle.job_id)
                if drift:
                    with self._lock:
                        self._replan()
                ex.close()
        except BaseException as e:  # noqa: BLE001 - surfaced via wait()
            handle.error = e
            handle.error_tb = traceback.format_exc()
        finally:
            # departure bookkeeping runs for clean finishes AND crashes,
            # outside the job's own try: a failure while replanning the
            # SURVIVORS must not blame this (possibly successful) job
            self._on_job_exit(handle)

    # ------------------------------------------------------------------
    def _on_job_exit(self, handle: JobHandle) -> None:
        """Departure bookkeeping: deregister from scheduler + arbiter and
        redistribute the departed job's slice across the survivors.  A job
        that held ZERO bytes of the split (a finished under-demand job)
        reclaims nothing — re-splitting and replanning every survivor
        would rebuild the exact same plans, so the no-op replan is
        skipped."""
        handle.done = True
        handle.executor = None
        is_serve = _is_serve(handle)
        with self._lock:
            if self.experience is not None and not is_serve:
                # flush distilled experience BEFORE deregistering: the
                # hub still holds this job's records, the handle its
                # final plan.  Failures are recorded, never raised — the
                # store must not take a (possibly successful) job down.
                try:
                    self.cost_model.recalibrate(self.telemetry,
                                                report=False)
                    fp = handle.fingerprint \
                        or self.experience.fingerprint(handle.seq)
                    samples = self.telemetry.total_op_samples()
                    self.experience.record_job(
                        fp, seq=handle.seq, hub=self.telemetry,
                        job_id=handle.job_id, plan=handle.plan,
                        pipeline=self.scheduler.pipeline.name,
                        peak_bytes=max(
                            handle.peak_bytes,
                            self.accountant.job_peak(handle.job_id)),
                        calib=self.cost_model.calib,
                        calib_samples=samples)
                    self.experience.flush()
                except Exception as e:  # noqa: BLE001
                    self.experience_failures.append((handle.job_id, e))
                    self.events.warn("experience",
                                     "experience flush failed on job "
                                     "exit; distilled run lost",
                                     job_id=handle.job_id, error=repr(e))
            if self.drift is not None and not is_serve \
                    and handle.predicted_peak:
                measured = max(handle.peak_bytes,
                               self.accountant.job_peak(handle.job_id))
                if measured > 0:
                    fp = handle.fingerprint or ""
                    if not fp and self.experience is not None:
                        try:
                            fp = self.experience.fingerprint(handle.seq)
                        except Exception:  # noqa: BLE001
                            fp = ""
                    self.drift.observe(
                        fp or handle.job_id,
                        predicted_peak=handle.predicted_peak,
                        measured_peak=measured, job_id=handle.job_id)
                    if self.experience is not None:
                        try:  # persist the drift history now, not at
                            # the NEXT job's flush
                            self.experience.flush()
                        except Exception as e:  # noqa: BLE001
                            self.events.warn(
                                "experience", "drift-history flush "
                                "failed", job_id=handle.job_id,
                                error=repr(e))
            if not is_serve:
                self.scheduler.remove_job(handle.job_id)
            if self.arbiter is not None:
                reclaimed = self.arbiter.last_assignment.get(
                    handle.job_id, 0)
                self.arbiter.unregister(handle.job_id)
                if reclaimed == 0:
                    return
                try:
                    self._replan()
                except Exception as e:  # noqa: BLE001
                    # survivors keep their current (still valid) plans
                    self.replan_failures.append((handle.job_id, e))
                    self.events.warn("replan",
                                     "survivor replan failed after job "
                                     "departure; current plans kept",
                                     job_id=handle.job_id, error=repr(e))

    # ------------------------------------------------------------------
    def report_latencies(self, job_id: str, measured: List[float]) -> bool:
        with self._lock:
            if job_id not in self.scheduler.jobs:
                return False
            return self.scheduler.update_latencies(job_id, measured)

    def report_telemetry(self, job_id: str) -> bool:
        """Fold the hub's measured latencies into the job's sequence and
        return whether the hub reports drift past the replan threshold.
        The cost model recalibrates from the same new samples (O(new
        samples), per-job cursors), closing the capture-time loop: the
        NEXT ``launch()`` estimates latencies from measured constants,
        not the probe defaults the process started with."""
        with self._lock:
            if job_id not in self.scheduler.jobs:
                return False
            self.cost_model.recalibrate(self.telemetry, report=False)
            return self.scheduler.update_latencies_from_hub(
                job_id, self.telemetry)

    def failures(self) -> Dict[str, BaseException]:
        """Failed jobs so far (job_id -> exception)."""
        return {j: h.error for j, h in self.jobs.items()
                if h.error is not None}

    def wait(self, timeout: Optional[float] = None,
             raise_errors: bool = True) -> None:
        """Join every job thread, then surface job-thread failures loudly:
        all of them at once (``JobFailedError.failures``/``tracebacks``),
        with the first original exception chained as the cause.  Failures
        are raised even when the timeout expires before every thread
        joins — a dead job must not be masked by a slow one."""
        deadline = None if timeout is None else _time.time() + timeout
        for h in list(self.jobs.values()):
            if h.thread is None:
                continue
            remaining = None if deadline is None else max(0.0, deadline - _time.time())
            h.thread.join(remaining)
        failures = self.failures()
        if failures and raise_errors:
            tbs = {j: self.jobs[j].error_tb for j in failures
                   if self.jobs[j].error_tb}
            err = JobFailedError(failures, tbs)
            raise err from next(iter(failures.values()))

    @property
    def global_peak_bytes(self) -> int:
        return self.accountant.peak

    @property
    def replan_count(self) -> int:
        return self._replan_count

    @property
    def preempt_count(self) -> int:
        """Mid-iteration plan hot-swaps requested so far (arbiter mode
        "preempt")."""
        return self._preempt_count
