"""Global Controller (paper §III-D, Fig. 3).

Owns the job registry for a device: launches each job's Executor on its own
thread, funnels measured operator latencies back to the Memory Scheduler,
triggers re-planning when latencies drift past the update threshold
(§IV-E), and distributes fresh plans — applied by each Executor at its next
iteration boundary, exactly as the paper specifies ("the system will apply
the new plan right before computing the next batch of data").

The four-step scheduling procedure of §III-D maps to:
  1. `launch()`      — collect the new job's graph + cold-start latencies
                       (CostModel / LatencyMLP prediction, no passive mode)
  2. `_replan()`     — Memory Scheduler generates/updates the plans
  3. Executor threads + the shared AsyncSwapExecutor run the plans
  4. latency reports — EWMA-folded; drift beyond threshold triggers 2.
"""
from __future__ import annotations

import dataclasses
import threading
import time as _time
from typing import Any, Callable, Dict, List, Optional

from .access import AccessSequence
from .cost_model import CostModel, EWMATracker
from .engine import DeviceLedger, DmaChannel, MemoryEngine
from .executor import JaxprExecutor
from .graph_capture import capture_train_step
from .plan import MachineProfile, SchedulingPlan
from .scheduler import MemoryScheduler, SchedulerConfig


@dataclasses.dataclass
class JobHandle:
    job_id: str
    seq: AccessSequence
    closed_jaxpr: Any
    args: tuple
    iterations: int
    thread: Optional[threading.Thread] = None
    plan: Optional[SchedulingPlan] = None
    plan_version: int = 0
    done: bool = False
    error: Optional[BaseException] = None
    stats: List[Any] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)
    peak_bytes: int = 0


class GlobalController:
    def __init__(self, profile: Optional[MachineProfile] = None,
                 scheduler_config: Optional[SchedulerConfig] = None,
                 cost_model: Optional[CostModel] = None,
                 device_capacity: Optional[int] = None,
                 async_swap: bool = True):
        self.profile = profile or MachineProfile()
        self.scheduler = MemoryScheduler(self.profile, scheduler_config)
        self.cost_model = cost_model or CostModel()
        # one engine ledger + DMA channel shared by every job on the device
        self.engine = MemoryEngine(self.profile,
                                   capacity_bytes=device_capacity)
        self.accountant: DeviceLedger = self.engine.ledger
        self.channel: DmaChannel = self.engine.channel
        self.async_swap = async_swap
        self.jobs: Dict[str, JobHandle] = {}
        self.ewma: Dict[str, EWMATracker] = {}
        self._lock = threading.Lock()
        self._replan_count = 0

    # ------------------------------------------------------------------
    def launch(self, step_fn: Callable, params, opt_state, batch,
               job_id: str, iterations: int = 3,
               schedule: bool = True) -> JobHandle:
        """Register + start a training job (async, like the paper's
        sub-process per Executor)."""
        # reflect current device contention into cold-start predictions
        self.cost_model.utilization = min(
            0.9, 0.3 * sum(1 for j in self.jobs.values() if not j.done))
        seq, closed = capture_train_step(
            step_fn, params, opt_state, batch, job_id=job_id,
            cost_model=self.cost_model)
        handle = JobHandle(job_id=job_id, seq=seq, closed_jaxpr=closed,
                           args=(params, opt_state, batch),
                           iterations=iterations)
        with self._lock:
            self.jobs[job_id] = handle
            self.ewma[job_id] = EWMATracker(
                alpha=self.scheduler.config.ewma_alpha)
            self.scheduler.register_job(seq)
            if schedule:
                self._replan()
        t = threading.Thread(target=self._run_job, args=(handle,), daemon=True)
        handle.thread = t
        t.start()
        return handle

    # ------------------------------------------------------------------
    def _replan(self) -> None:
        """Memory Scheduler pass over all live jobs; distribute plans."""
        live = [j for j, h in self.jobs.items() if not h.done]
        if not live:
            return
        result = self.scheduler.schedule(live)
        for j in live:
            h = self.jobs[j]
            h.plan = result.plans[j]
            h.plan_version += 1
        self._replan_count += 1

    # ------------------------------------------------------------------
    def _run_job(self, handle: JobHandle) -> None:
        try:
            args = handle.args
            version_used = -1
            ex: Optional[JaxprExecutor] = None
            for it in range(handle.iterations):
                with self._lock:
                    plan = handle.plan
                    version = handle.plan_version
                if ex is None or version != version_used:
                    if ex is not None:
                        ex.close()
                    # carry the host store across plan versions
                    old_host = ex.host if ex is not None else {}
                    old_compressed = (set(ex.ctx.host_compressed)
                                      if ex is not None else set())
                    ex = JaxprExecutor(
                        handle.closed_jaxpr, handle.seq, plan,
                        accountant=self.accountant, channel=self.channel,
                        async_swap=self.async_swap, measure_latency=True)
                    ex.host.update(old_host)
                    ex.ctx.host_compressed |= old_compressed
                    version_used = version
                else:
                    # fresh per-iteration stores, persistent host cache
                    # (incl. which parked copies are quantized — fetching
                    # them must go through the dequantize path)
                    host = ex.host
                    compressed = set(ex.ctx.host_compressed)
                    ex = JaxprExecutor(
                        handle.closed_jaxpr, handle.seq, plan,
                        accountant=self.accountant, channel=self.channel,
                        async_swap=self.async_swap, measure_latency=True)
                    ex.host.update(host)
                    ex.ctx.host_compressed |= compressed
                t0 = _time.perf_counter()
                outs = ex.run(*args)
                handle.step_times.append(_time.perf_counter() - t0)
                handle.stats.append(ex.stats)
                handle.peak_bytes = max(handle.peak_bytes, ex.stats.peak_bytes)
                # feed params/opt-state back (outputs 0,1 by convention)
                n_p = len(__import__("jax").tree.flatten(args[0])[0])
                n_o = len(__import__("jax").tree.flatten(args[1])[0])
                import jax as _jax
                p = _jax.tree.unflatten(_jax.tree.structure(args[0]),
                                        outs[:n_p])
                o = _jax.tree.unflatten(_jax.tree.structure(args[1]),
                                        outs[n_p:n_p + n_o])
                args = (p, o, args[2])
                # report measured latencies (paper step 4)
                if ex.stats.op_latencies:
                    drift = self.report_latencies(handle.job_id,
                                                  ex.stats.op_latencies)
                    if drift:
                        with self._lock:
                            self._replan()
                ex.close()
            handle.done = True
            with self._lock:
                self.scheduler.remove_job(handle.job_id)
        except BaseException as e:  # noqa: BLE001 - surfaced to caller
            handle.error = e
            handle.done = True

    # ------------------------------------------------------------------
    def report_latencies(self, job_id: str, measured: List[float]) -> bool:
        with self._lock:
            if job_id not in self.scheduler.jobs:
                return False
            return self.scheduler.update_latencies(job_id, measured)

    def wait(self, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else _time.time() + timeout
        for h in list(self.jobs.values()):
            if h.thread is None:
                continue
            remaining = None if deadline is None else max(0.0, deadline - _time.time())
            h.thread.join(remaining)
        for h in self.jobs.values():
            if h.error is not None:
                raise h.error

    @property
    def global_peak_bytes(self) -> int:
        return self.accountant.peak

    @property
    def replan_count(self) -> int:
        return self._replan_count
