"""Complete scheduling loop (paper §IV-F, Algorithm 3) + plan updating (§IV-E).

The Memory Scheduler iterates: activity analysis → merged peak analysis →
greedy swap scheduling until no tensor can be swapped → MSPS-ranked
recomputation while the predicted peak still exceeds the budget.  Stops when
the average peak reduction over the past 3 iterations is below 0.05 % after
100 iterations (paper Alg 3 line 4).

Plan updating: the Executor keeps reporting measured operator latencies; when
the summed latency drifts by more than `update_threshold` relative to the
sum used for the last plan, the scheduler rebuilds the Tensor Access Sequence
(EWMA-corrected latencies) and replans from scratch (§IV-E).
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Dict, List, Optional, Sequence

from .access import AccessSequence
from .peak_analysis import PeakReport, analyze
from .plan import MachineProfile, SchedulingPlan
from .recompute_planner import RecomputePlanner, plan_one_recompute
from .swap_planner import SwapPlanner, plan_one_swap


@dataclasses.dataclass
class SchedulerConfig:
    memory_budget_bytes: Optional[int] = None   # None: device size from profile
    max_swap_ratio: float = 1.0                 # per-job MSR limit (can be dict)
    per_job_swap_ratio: Optional[Dict[str, float]] = None
    min_improvement: float = 5e-4               # 0.05 % (paper Alg 3)
    patience_iters: int = 100
    patience_window: int = 3
    update_threshold: float = 0.2               # latency-drift replan trigger
    ewma_alpha: float = 0.3
    max_iterations: int = 10000


@dataclasses.dataclass
class ScheduleResult:
    plans: Dict[str, SchedulingPlan]
    initial_report: PeakReport
    final_report: PeakReport
    iterations: int
    swaps_scheduled: int
    recomputes_scheduled: int
    plan_wallclock_s: float

    @property
    def memory_saving_ratio(self) -> float:
        """MSR against the merged vanilla peak (paper §V-A)."""
        v = self.initial_report.peak_bytes
        return (v - self.final_report.peak_bytes) / v if v else 0.0


class MemoryScheduler:
    """Global scheduler over all registered jobs (paper Fig. 3)."""

    def __init__(self, profile: Optional[MachineProfile] = None,
                 config: Optional[SchedulerConfig] = None):
        self.profile = profile or MachineProfile()
        self.config = config or SchedulerConfig()
        self.jobs: Dict[str, AccessSequence] = {}
        self.offsets: Dict[str, float] = {}
        # latency sums used for the last plan, per job (drift detection)
        self._plan_latency_sum: Dict[str, float] = {}

    # ------------------------------------------------------------------
    def register_job(self, seq: AccessSequence, offset: float = 0.0) -> None:
        self.jobs[seq.job_id] = seq
        self.offsets[seq.job_id] = offset
        self._plan_latency_sum[seq.job_id] = sum(
            op.latency for op in seq.operators)

    def remove_job(self, job_id: str) -> None:
        self.jobs.pop(job_id, None)
        self.offsets.pop(job_id, None)
        self._plan_latency_sum.pop(job_id, None)

    # ------------------------------------------------------------------
    def update_latencies(self, job_id: str, measured: Sequence[float]) -> bool:
        """EWMA-correct a job's operator latencies with runtime measurements;
        returns True if the drift exceeds the replan threshold (§IV-E)."""
        seq = self.jobs[job_id]
        a = self.config.ewma_alpha
        new = [a * m + (1 - a) * op.latency
               for m, op in zip(measured, seq.operators)]
        seq.set_latencies(new)
        s_old = self._plan_latency_sum.get(job_id, 0.0)
        s_new = sum(new)
        if s_old <= 0:
            return True
        return abs(s_new - s_old) / s_old > self.config.update_threshold

    # ------------------------------------------------------------------
    def schedule(self, job_ids: Optional[Sequence[str]] = None) -> ScheduleResult:
        """Algorithm 3 over the merged timeline of the given (default: all)
        registered jobs."""
        t0 = _time.perf_counter()
        cfg = self.config
        ids = list(job_ids) if job_ids is not None else list(self.jobs)
        seqs = [self.jobs[j] for j in ids]
        budget = cfg.memory_budget_bytes or self.profile.device_memory_bytes

        plans = {j: SchedulingPlan(job_id=j) for j in ids}
        # activity analysis (paper Alg 3 line 2): release at last use is the
        # baseline behaviour encoded directly in peak analysis; explicit map
        # kept on the plan for the executor.
        for j in ids:
            plans[j].release_after_op = {}

        swap_planners = {
            j: SwapPlanner(self.jobs[j], plans[j], self.profile,
                           (cfg.per_job_swap_ratio or {}).get(
                               j, cfg.max_swap_ratio))
            for j in ids}
        rec_planners = {j: RecomputePlanner(self.jobs[j], plans[j])
                        for j in ids}

        # vanilla normalizer (paper platform: no free-at-last-use)
        initial = analyze(seqs, plans=None, offsets=self.offsets,
                          free_at_last_use=False)
        report = analyze(seqs, plans=plans, offsets=self.offsets)
        history: List[int] = [report.peak_bytes]
        swap_ok, rec_ok = True, True
        n_swaps = n_recs = iters = 0

        while swap_ok or rec_ok:
            if iters >= cfg.max_iterations:
                break
            # paper Alg 3 line 4: early stop on stagnation
            if iters > cfg.patience_iters and len(history) > cfg.patience_window:
                prev = history[-cfg.patience_window - 1]
                cur = history[-1]
                if prev > 0 and (prev - cur) / prev < cfg.min_improvement:
                    break
            if swap_ok:
                swap_ok = plan_one_swap(swap_planners, report)
                if swap_ok:
                    n_swaps += 1
            elif report.peak_bytes >= budget and rec_ok:
                rec_ok = plan_one_recompute(rec_planners, report)
                if rec_ok:
                    n_recs += 1
            else:
                break
            report = analyze(seqs, plans=plans, offsets=self.offsets)
            history.append(report.peak_bytes)
            iters += 1

        wall = _time.perf_counter() - t0
        for j in ids:
            plans[j].vanilla_peak_bytes = initial.per_job_peak.get(j, 0)
            plans[j].planned_peak_bytes = report.per_job_peak.get(j, 0)
            plans[j].plan_wallclock_s = wall
            self._plan_latency_sum[j] = sum(
                op.latency for op in self.jobs[j].operators)
        return ScheduleResult(
            plans=plans, initial_report=initial, final_report=report,
            iterations=iters, swaps_scheduled=n_swaps,
            recomputes_scheduled=n_recs, plan_wallclock_s=wall)


def schedule_single(seq: AccessSequence,
                    profile: Optional[MachineProfile] = None,
                    budget_bytes: Optional[int] = None,
                    max_swap_ratio: float = 1.0) -> ScheduleResult:
    """Convenience one-job entry point (paper §V-B single-workload setup:
    MSR limit 100 %)."""
    sched = MemoryScheduler(
        profile=profile,
        config=SchedulerConfig(memory_budget_bytes=budget_bytes,
                               max_swap_ratio=max_swap_ratio))
    sched.register_job(seq)
    return sched.schedule()
