"""Memory Scheduler front-end (paper §IV-F, Algorithm 3) + plan updating
(§IV-E).

The convergence loop itself — greedy swap scheduling until no tensor can be
swapped, then MSPS-ranked recomputation while the predicted peak still
exceeds the budget, with the paper's patience/min-improvement stopping rule
— lives in ``passes.Pipeline``; the TENSILE policy is the pass configuration
``Pipeline([SwapPass, RecomputePass], cross_iteration=True)``.  This module
keeps the *runtime* responsibilities: the job registry, EWMA latency
correction, and the drift-triggered replan decision.

Plan updating: the Executor keeps reporting measured operator latencies; when
the summed latency drifts by more than `update_threshold` relative to the
sum used for the last plan, the scheduler rebuilds the Tensor Access Sequence
(EWMA-corrected latencies) and replans from scratch (§IV-E).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

from .access import AccessSequence
from .passes import (Pipeline, ScheduleResult, SchedulerConfig,
                     build_pipeline)
from .plan import MachineProfile, SchedulingPlan

__all__ = ["MemoryScheduler", "ScheduleResult", "SchedulerConfig",
           "schedule_single"]


class MemoryScheduler:
    """Global scheduler over all registered jobs (paper Fig. 3)."""

    def __init__(self, profile: Optional[MachineProfile] = None,
                 config: Optional[SchedulerConfig] = None,
                 pipeline: Optional[Pipeline] = None,
                 experience=None):
        self.profile = profile or MachineProfile()
        self.config = config or SchedulerConfig()
        # the planning policy; defaults to the paper's TENSILE pipeline but
        # any registered pipeline (or a custom pass list) drops in
        self.pipeline = pipeline or build_pipeline(
            "tensile", profile=self.profile, config=self.config)
        # experience plane: an ExperienceStore makes `schedule` consult
        # the per-fingerprint plan cache (verified warm starts) and seeds
        # swap windows from persisted bandwidth — see passes.Pipeline
        if experience is not None and self.pipeline.experience is None:
            self.pipeline.experience = experience
        self.jobs: Dict[str, AccessSequence] = {}
        self.offsets: Dict[str, float] = {}
        self.priorities: Dict[str, float] = {}
        # construction-time config values are the caller's: they are
        # restored (not clobbered) whenever a replan has no arbiter split
        # or a job no registered priority
        self._static_budgets = (dict(self.config.per_job_budget_bytes)
                                if self.config.per_job_budget_bytes
                                else None)
        self._static_priorities = dict(self.config.job_priorities or {})
        # latency sums used for the last plan, per job (drift detection)
        self._plan_latency_sum: Dict[str, float] = {}

    # ------------------------------------------------------------------
    def register_job(self, seq: AccessSequence, offset: float = 0.0,
                     priority: Optional[float] = None) -> None:
        self.jobs[seq.job_id] = seq
        self.offsets[seq.job_id] = offset
        if priority is not None:
            self.priorities[seq.job_id] = priority
        self._plan_latency_sum[seq.job_id] = sum(
            op.latency for op in seq.operators)

    def priority_of(self, job_id: str) -> float:
        """Effective priority: registered value, else the caller's
        construction-time config, else 1.0."""
        return self.priorities.get(
            job_id, self._static_priorities.get(job_id, 1.0))

    def remove_job(self, job_id: str) -> None:
        self.jobs.pop(job_id, None)
        self.offsets.pop(job_id, None)
        self.priorities.pop(job_id, None)
        self._plan_latency_sum.pop(job_id, None)

    # ------------------------------------------------------------------
    def update_latencies(self, job_id: str, measured: Sequence[float]) -> bool:
        """EWMA-correct a job's operator latencies with runtime measurements;
        returns True if the drift exceeds the replan threshold (§IV-E)."""
        seq = self.jobs[job_id]
        a = self.config.ewma_alpha
        new = [a * m + (1 - a) * op.latency
               for m, op in zip(measured, seq.operators)]
        seq.set_latencies(new)
        s_old = self._plan_latency_sum.get(job_id, 0.0)
        s_new = sum(new)
        if s_old <= 0:
            return True
        return abs(s_new - s_old) / s_old > self.config.update_threshold

    def update_latencies_from_hub(self, job_id: str, hub) -> bool:
        """Hub-fed §IV-E correction (the measured-telemetry plane): fold
        the TelemetryHub's EWMA-corrected measured latencies into the
        job's sequence, and judge the replan decision by the HUB's drift
        ratio against the latency sum the current plan was built from —
        drift detection no longer lives in scheduler-private EWMA deltas.
        Ops the hub has no sample for yet keep their modeled latency
        (cold-start blending)."""
        if job_id not in self.jobs:
            return False
        seq = self.jobs[job_id]
        measured = hub.op_latencies(job_id)
        if not measured:
            return False
        a = self.config.ewma_alpha
        new = [a * measured[i] + (1 - a) * op.latency if i in measured
               else op.latency
               for i, op in enumerate(seq.operators)]
        seq.set_latencies(new)
        s_old = self._plan_latency_sum.get(job_id, 0.0)
        if s_old <= 0:
            return True
        return hub.drift_ratio(job_id, s_old) > self.config.update_threshold

    # ------------------------------------------------------------------
    def schedule(self, job_ids: Optional[Sequence[str]] = None,
                 budgets: Optional[Dict[str, int]] = None) -> ScheduleResult:
        """One pipeline run over the merged timeline of the given (default:
        all) registered jobs.

        `budgets` are the BudgetArbiter's per-job byte assignments for this
        replan; they (and the registered priorities) are published into the
        shared SchedulerConfig so budget-aware passes (PriorityPass,
        BudgetAutoscalePass) plan against the arbiter split instead of the
        full device.  Both change across replans — budget is an *input* of
        a plan, not a constant of the scheduler."""
        ids = list(job_ids) if job_ids is not None else list(self.jobs)
        seqs = [self.jobs[j] for j in ids]
        # registered priorities overlay construction-time config ones
        self.config.job_priorities = {
            j: self.priorities.get(j, self._static_priorities.get(j, 1.0))
            for j in ids}
        # rebuilt every replan — a replan without an arbiter split must not
        # re-enforce a previous split's stale slices, but it does restore
        # any static per-job budgets the caller configured up front
        self.config.per_job_budget_bytes = (
            {j: budgets[j] for j in ids if j in budgets}
            if budgets is not None
            else (dict(self._static_budgets)
                  if self._static_budgets else None))
        result = self.pipeline.plan(
            seqs, offsets={j: self.offsets[j] for j in ids})
        for j in ids:
            self._plan_latency_sum[j] = sum(
                op.latency for op in self.jobs[j].operators)
        return result

    # ------------------------------------------------------------------
    def replan_from(self, job_id: str, prior_plan: "SchedulingPlan",
                    step: int, budget_bytes: int) -> ScheduleResult:
        """Incremental remainder replan for one job against a shrunken
        slice (preemptive arbitration): delegates to
        ``Pipeline.replan_from`` with the job's registered sequence.  The
        returned plan extends ``prior_plan`` with eager swap-outs strictly
        after safe-point op ``step``, so the controller can hot-swap it
        into the running executor at that safe point."""
        seq = self.jobs[job_id]
        return self.pipeline.replan_from(
            [seq], {job_id: prior_plan}, {job_id: step},
            budgets={job_id: budget_bytes})


def schedule_single(seq: AccessSequence,
                    profile: Optional[MachineProfile] = None,
                    budget_bytes: Optional[int] = None,
                    max_swap_ratio: float = 1.0,
                    pipeline_name: str = "tensile") -> ScheduleResult:
    """Convenience one-job entry point (paper §V-B single-workload setup:
    MSR limit 100 %)."""
    profile = profile or MachineProfile()
    config = SchedulerConfig(memory_budget_bytes=budget_bytes,
                             max_swap_ratio=max_swap_ratio)
    sched = MemoryScheduler(
        profile=profile, config=config,
        pipeline=build_pipeline(pipeline_name, profile=profile,
                                config=config))
    sched.register_job(seq)
    return sched.schedule()
