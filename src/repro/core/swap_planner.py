"""Swap Event scheduling (paper §IV-A, Algorithm 1).

Greedy: pick the largest tensor among those causing the memory peak (MPT),
compute the feasible time regions of its Swap-Out / Swap-In events under the
three constraints of §IV-A —

  1. swap-out starts after the tensor's TGA and ends before the peak instant;
     swap-in starts after the swap-out ends and finishes before the next TUA;
  2. the single host-DMA (PCIe) channel carries one transfer at a time;
  3. a swap event must not overlap the tensor's own accesses —

and place the swap-out as early and the swap-in as late as possible.  Updated
parameters (Opt phase) are scheduled **across the iteration boundary**: their
swap-in targets the first TUA of the aliased parameter in the *next*
iteration (paper Fig. 1(c)).  Because steady-state execution is periodic with
the iteration period T, the planner works in wrapped time modulo T with a
periodic channel reservation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .access import AccessSequence, TensorKind
from .peak_analysis import PERSISTENT_KINDS, PeakReport, storage_of
from .plan import (ChannelReservation, EventType, MachineProfile,
                   ScheduleEvent, SchedulingPlan, wrap_intervals)

EPS = 1e-9


class PeriodicChannel:
    """Single-transfer channel with period-T wrapped bookings.

    An interval that crosses the iteration boundary is split into
    ``[s, T) + [0, e-T)``; in steady state every iteration repeats the same
    occupancy, so one wrapped period describes the channel fully.
    """

    def __init__(self, period: float):
        self.period = float(period)
        self._res = ChannelReservation()

    def _pieces(self, start: float, duration: float) -> List[List[float]]:
        return wrap_intervals(start, duration, self.period)

    def is_free(self, start: float, duration: float) -> bool:
        return all(self._res.is_free(s, e) for s, e in self._pieces(start, duration))

    def book(self, start: float, duration: float) -> None:
        for s, e in self._pieces(start, duration):
            self._res.book(s, e)

    def release(self, start: float, duration: float) -> None:
        for s, e in self._pieces(start, duration):
            self._res.release(s, e)

    def earliest_fit(self, lo: float, hi: float, duration: float,
                     blocked: Sequence[Tuple[float, float]] = ()) -> Optional[float]:
        """Earliest start in [lo, hi - duration] whose transfer fits the
        channel and avoids `blocked` (absolute, unwrapped) intervals."""
        return self._scan(lo, hi, duration, blocked, latest=False)

    def latest_fit(self, lo: float, hi: float, duration: float,
                   blocked: Sequence[Tuple[float, float]] = ()) -> Optional[float]:
        return self._scan(lo, hi, duration, blocked, latest=True)

    def _scan(self, lo: float, hi: float, duration: float,
              blocked: Sequence[Tuple[float, float]], latest: bool) -> Optional[float]:
        if hi - lo < duration - EPS:
            return None
        # candidate start points: region edges, ends of channel bookings and
        # blocked intervals (projected into every period copy inside [lo, hi]);
        # scanned in preference order with early exit (the planner issues
        # millions of fit queries on large graphs)
        cands = {lo, hi - duration}
        T = self.period
        k0 = int(lo // T)
        k1 = int(hi // T) + 1
        for s, e in self._res.intervals():
            for k in range(k0, k1 + 1):
                cands.add(k * T + e)          # start right after a booking
                cands.add(k * T + s - duration)  # end right before one
        for s, e in blocked:
            cands.add(e)
            cands.add(s - duration)
        ordered = sorted(cands, reverse=latest)
        for c in ordered:
            if not (lo - EPS <= c and c + duration <= hi + EPS):
                continue
            if self.is_free(c, duration) \
                    and not _overlaps_any(c, c + duration, blocked):
                return c
        return None


def _overlaps_any(s: float, e: float, blocked: Sequence[Tuple[float, float]]) -> bool:
    return any(bs < e - EPS and s < be - EPS for bs, be in blocked)


@dataclasses.dataclass
class SwapAttempt:
    succeeded: bool
    succeed_swap_out: bool
    have_first_access: bool
    events: List[ScheduleEvent] = dataclasses.field(default_factory=list)


class SwapPlanner:
    """Per-job Algorithm 1 state.  The cross-job conflict is mitigated by the
    max-swapping-ratio limit (paper §IV-A), not by cross-job channel
    coordination — jobs run asynchronously so event order across jobs is not
    controllable."""

    def __init__(self, seq: AccessSequence, plan: SchedulingPlan,
                 profile: MachineProfile,
                 max_swap_ratio: float = 1.0,
                 cross_iteration: bool = True,
                 compressed: bool = False,
                 max_tensor_bytes: Optional[int] = None,
                 not_before: float = 0.0,
                 telemetry=None,
                 experience=None):
        self.seq = seq
        self.plan = plan
        self.profile = profile
        self.max_swap_ratio = max_swap_ratio
        # measured-telemetry plane: when a hub with enough transfer
        # samples is attached, swap windows are sized from the MEASURED
        # DMA bandwidth instead of the profile constant — `not_before`
        # feasibility and planned-vs-real overlap are then judged against
        # what the channel actually sustains.  None (the default) keeps
        # the modeled constants, so plans stay byte-reproducible.
        self.telemetry = telemetry
        # experience plane: between a cold start and the first live
        # transfer samples, windows are sized from the bandwidth a PRIOR
        # run measured and persisted (ExperienceStore) — live telemetry,
        # once present, always wins over stored experience.  Resolved
        # ONCE here: the stored value is static for the process and
        # _swap_time sits inside the Alg.-3 convergence hot loop (a
        # per-call store read would hit disk thousands of times)
        self.experience = experience
        self._experience_bw: Optional[float] = None
        if experience is not None:
            try:
                self._experience_bw = experience.bandwidth(
                    compressed=compressed)
            except Exception:   # noqa: BLE001 - corrupt store: modeled path
                self._experience_bw = None
        # incremental replans (safe-point hot-swap) must not schedule new
        # events before the splice instant — the past already executed
        self.not_before = not_before
        # False restricts scheduling to within one iteration (no Opt-phase
        # updated-param events — the Capuchin limitation TENSILE lifts)
        self.cross_iteration = cross_iteration
        # compressed=True routes transfers through the quantize-on-offload
        # path: shorter channel bookings (CompressedOffloadPass); an optional
        # size cap keeps quantization error confined to small tensors
        self.compressed = compressed
        self.max_tensor_bytes = max_tensor_bytes
        self.channel = PeriodicChannel(max(seq.iteration_time, EPS))
        self.swapped: set = set(plan.swapped_tensors())
        # structural inputs (swappable count + storage -> candidate tensor
        # ids, updated-param aliases first): with an ExperienceStore
        # attached these come from its per-fingerprint JobPassState memo —
        # identical values, skipping the O(tensors) reconstruction every
        # replan pays (plan_one_swap runs once per greedy iteration over
        # thousands of MPT entries; a per-entry full-tensor scan is
        # quadratic)
        ps = None
        if experience is not None:
            try:
                ps = experience.pass_state(seq)
            except Exception:   # noqa: BLE001 - corrupt store: cold path
                ps = None
        if ps is None:
            from .experience import default_pass_state
            ps = default_pass_state(seq)
        self._swappable_total = ps.swappable_total
        self.alias_candidates: Dict[str, List[str]] = ps.alias_candidates
        # re-book existing events (planner may be re-run after latency drift)
        for ev in plan.events:
            if ev.event_type in (EventType.SWAP_OUT, EventType.SWAP_IN):
                try:
                    self.channel.book(ev.start, ev.duration)
                except ValueError:
                    pass

    # ------------------------------------------------------------------
    def _swap_time(self, size_bytes: int) -> float:
        if self.telemetry is not None:
            bw = self.telemetry.measured_bandwidth(
                compressed=self.compressed)
            if bw:
                # measured effective bandwidth for the size-dependent
                # term; the per-transfer setup cost stays the profile's
                return self.profile.host_link_latency + size_bytes / bw
        if self._experience_bw:
            return self.profile.host_link_latency \
                + size_bytes / self._experience_bw
        return self.profile.transfer_time(size_bytes,
                                          compressed=self.compressed)

    # ------------------------------------------------------------------
    def swap_ratio(self) -> float:
        return len(self.swapped) / self._swappable_total

    def ratio_allows(self) -> bool:
        return self.swap_ratio() < self.max_swap_ratio - EPS

    # ------------------------------------------------------------------
    def _own_access_blocks(self, tid: str) -> List[Tuple[float, float]]:
        """Constraint 3: swap events cannot overlap the tensor's accesses."""
        return [(a.time, a.end_time) for a in self.seq.tensor_accesses(tid)
                if a.end_time > a.time]

    def _trigger_for(self, start: float) -> Tuple[int, float]:
        """Map an absolute instant to (trigger op, Δtime) — the plan's native
        event encoding (paper §III-D)."""
        t = start % max(self.seq.iteration_time, EPS)
        trig = -1
        for i, end in enumerate(self.seq.op_end):
            if end <= t + EPS:
                trig = i
            else:
                break
        base = self.seq.op_end[trig] if trig >= 0 else 0.0
        return trig, t - base

    def _mk_event(self, et: EventType, tid: str, start: float, dur: float,
                  target_op: Optional[int] = None,
                  crosses: bool = False) -> ScheduleEvent:
        trig, delta = self._trigger_for(start)
        spec = self.seq.tensors[tid]
        return ScheduleEvent(
            event_type=et, tensor_id=tid, job_id=self.seq.job_id,
            trigger_op=trig, delta=delta, start=start, end=start + dur,
            size_bytes=spec.size_bytes, target_op=target_op,
            crosses_iteration=crosses, compressed=self.compressed)

    # ------------------------------------------------------------------
    def scheduling_swap(self, tid: str, latest_time: float) -> SwapAttempt:
        """Paper Algorithm 1 `scheduling_swap` for one tensor."""
        seq, prof = self.seq, self.profile
        spec = seq.tensors[tid]
        dur = self._swap_time(spec.size_bytes)
        tga = seq.tga(tid)
        is_updated_param = spec.updates is not None
        # persistent tensors resident from iteration start can leave any time
        earliest = tga.time if tga is not None else 0.0
        earliest = max(earliest, self.not_before)
        blocked = self._own_access_blocks(tid)
        attempt = SwapAttempt(False, False, False)
        T = max(seq.iteration_time, EPS)

        while latest_time - earliest > EPS:
            out_start = self.channel.earliest_fit(earliest, latest_time, dur, blocked)
            if out_start is None:
                return attempt
            out_end = out_start + dur
            attempt.succeed_swap_out = True

            # --- find the access the swap-in must beat -------------------
            if is_updated_param:
                # across-iteration: first TUA of the aliased parameter in the
                # next iteration (paper Alg 1 line 8-9)
                first = seq.first_tua(spec.updates)
                in_lo = out_end
                in_hi = (T + first.time) if first is not None else 0.0
                crosses = True
            else:
                first = seq.first_tua_after(tid, out_end)
                in_lo = out_end
                in_hi = first.time if first is not None else 0.0
                crosses = False

            if first is None:
                if spec.kind in PERSISTENT_KINDS or spec.kind is TensorKind.OUTPUT \
                        or is_updated_param:
                    # never used again this horizon: eviction alone suffices,
                    # host copy preserves the data
                    self.channel.book(out_start, dur)
                    ev = self._mk_event(EventType.SWAP_OUT, tid, out_start, dur)
                    self.plan.add(ev)
                    attempt.events.append(ev)
                    attempt.succeeded = True
                return attempt
            attempt.have_first_access = True

            in_start = self.channel.latest_fit(in_lo, in_hi, dur, blocked)
            if in_start is not None:
                self.channel.book(out_start, dur)
                self.channel.book(in_start, dur)
                out_ev = self._mk_event(EventType.SWAP_OUT, tid, out_start, dur)
                in_ev = self._mk_event(EventType.SWAP_IN, tid, in_start, dur,
                                       target_op=first.op_idx, crosses=crosses)
                self.plan.add(out_ev)
                self.plan.add(in_ev)
                attempt.events += [out_ev, in_ev]
                attempt.succeeded = True
                # paper: "try to swap-in the rest of accesses greedily" — the
                # host copy persists, so later gaps only need release+swap-in
                if not is_updated_param:
                    self._swap_in_rest(tid, first, dur, blocked)
                return attempt
            # swap-in did not fit before `first`; retry with the swap-out
            # moved past this access (paper Alg 1 line 18-21)
            earliest = max(first.end_time, out_end)
        return attempt

    def _swap_in_rest(self, tid: str, first, dur: float,
                      blocked: List[Tuple[float, float]]) -> None:
        accs = [a for a in self.seq.tensor_accesses(tid)
                if not a.is_tga and a.time > first.time + EPS]
        prev = first
        for a in accs:
            in_start = self.channel.latest_fit(prev.end_time, a.time, dur, blocked)
            if in_start is not None and in_start >= prev.end_time:
                self.channel.book(in_start, dur)
                # release after the previous access, prefetch before this one
                rel = self._mk_event(EventType.RELEASE, tid, prev.end_time, 0.0)
                in_ev = self._mk_event(EventType.SWAP_IN, tid, in_start, dur,
                                       target_op=a.op_idx)
                self.plan.add(rel)
                self.plan.add(in_ev)
            prev = a

    # ------------------------------------------------------------------
    def try_swap_tensor(self, tid: str, peak_time: float) -> bool:
        """Outer loop body of Algorithm 1 (lines 23-34) for one MPT member."""
        seq = self.seq
        spec = seq.tensors.get(tid)
        if spec is None or tid in self.swapped:
            return False
        if (self.max_tensor_bytes is not None
                and spec.size_bytes > self.max_tensor_bytes):
            return False
        accs = seq.tensor_accesses(tid)
        is_updated_param = spec.updates is not None
        if is_updated_param or spec.kind in PERSISTENT_KINDS:
            if not self.cross_iteration:
                return False
            # Opt-phase tensors (paper Alg 1 line 26-27): always eligible —
            # across-iteration scheduling is the point of TENSILE.  The
            # swap-out window extends into the next iteration's prefix,
            # up to the aliased parameter's first TUA (paper Fig. 1(c)).
            T = max(seq.iteration_time, EPS)
            latest = T
            first = seq.first_tua(spec.updates or tid)
            if first is not None:
                latest = T + first.time
            att = self.scheduling_swap(tid, latest_time=latest)
            if att.succeeded:
                self.swapped.add(tid)
            return att.succeeded
        if not self.ratio_allows() or len(accs) <= 1:
            return False
        att = self.scheduling_swap(tid, latest_time=peak_time)
        if att.succeeded:
            self.swapped.add(tid)
        return att.succeeded


def plan_one_swap(planners: Dict[str, "SwapPlanner"],
                  report: PeakReport) -> bool:
    """One greedy step: try MPT members largest-first across all jobs
    (paper: "choose the biggest tensor among all jobs as the most valuable
    tensor to swap")."""
    for storage_id, job_id, _size in report.peak_tensors:
        pl = planners.get(job_id)
        if pl is None:
            continue
        # MPT carries storage ids; map back to swap candidates: prefer the
        # updated-parameter alias (Opt-phase swap) when one exists.
        for tid in pl.alias_candidates.get(storage_id, ()):
            if pl.try_swap_tensor(tid, report.peak_time):
                return True
    return False
