"""Static compute-graph capture: jaxpr → Tensor Access Sequence.

The paper describes jobs as static compute graphs G(V, E) "just like the one
in TensorFlow".  In JAX the natural equivalent is the jaxpr of the step
function: each equation is an operator in V; its (non-literal) input vars are
TUAs, its output vars TGAs.  Parameter / optimizer-state / input kinds are
recovered from the step function's pytree structure, and the updated-param →
old-param aliasing (paper §IV-B situation 2) from matching input and output
pytree paths.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.extend import core as jcore

from .access import (AccessSequence, Operator, Phase, TensorKind, TensorSpec)
from .cost_model import CostModel

OPT_PRIMITIVES = {"add_any", "mul", "sub", "add", "div", "sqrt", "integer_pow",
                  "rsqrt"}


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0


@dataclasses.dataclass
class CaptureSpec:
    """Labels the step function's arguments/results for kind recovery.

    arg_kinds / out_kinds: one TensorKind per top-level positional argument /
    result of the step function (broadcast over that subtree's leaves).
    alias_pairs: (out_pos, arg_pos) pairs whose pytrees match leaf-for-leaf —
    e.g. (new_params, params), (new_opt_state, opt_state).
    """
    arg_kinds: Sequence[TensorKind]
    out_kinds: Sequence[TensorKind] = ()
    alias_pairs: Sequence[Tuple[int, int]] = ()


def capture(fn: Callable, *args: Any, job_id: str = "job0",
            spec: Optional[CaptureSpec] = None,
            cost_model: Optional[CostModel] = None,
            phase_split: Optional[Callable[[jcore.JaxprEqn], Phase]] = None,
            experience=None,
            ) -> AccessSequence:
    """Trace `fn(*args)` and build its AccessSequence.

    `args` may be arrays or ShapeDtypeStructs (no allocation needed).

    `experience` (an ExperienceStore) warm-boots the default cost model:
    capture-time latency estimates then come from the calibration a prior
    run measured and persisted, not probe constants — the paper's
    cold-start fix for recurring workloads.  Ignored when an explicit
    `cost_model` is passed (it may already be warm-booted or deliberately
    cold).
    """
    cost_model = cost_model or CostModel(experience=experience)
    closed = jax.make_jaxpr(fn)(*args)
    jaxpr = closed.jaxpr

    # ---- label input leaves ------------------------------------------
    flat_per_arg: List[List[Any]] = []
    for a in args:
        leaves, _ = jax.tree.flatten(a)
        flat_per_arg.append(leaves)
    arg_kinds = list(spec.arg_kinds) if spec else [TensorKind.INPUT] * len(args)
    invar_kind: Dict[Any, TensorKind] = {}
    invar_argpos: Dict[Any, Tuple[int, int]] = {}
    i = 0
    for pos, leaves in enumerate(flat_per_arg):
        for k, _ in enumerate(leaves):
            if i < len(jaxpr.invars):
                invar_kind[jaxpr.invars[i]] = (
                    arg_kinds[pos] if pos < len(arg_kinds) else TensorKind.INPUT)
                invar_argpos[jaxpr.invars[i]] = (pos, k)
            i += 1

    # ---- label output leaves (aliasing for updated params) -----------
    out_alias: Dict[Any, Any] = {}   # outvar -> aliased invar
    out_kind: Dict[Any, TensorKind] = {}
    if spec:
        # count leaves per output position by evaluating output pytree shape
        out_avals = [v.aval for v in jaxpr.outvars]
        # assume out_kinds aligned with flattened structure per position if
        # the caller provides per-position leaf counts via eval_shape
        try:
            out_shape = jax.eval_shape(fn, *args)
            out_leaves_per_pos = [len(jax.tree.flatten(o)[0])
                                  for o in (out_shape if isinstance(out_shape, tuple)
                                            else (out_shape,))]
        except Exception:
            out_leaves_per_pos = [len(out_avals)]
        idx = 0
        pos_slices: Dict[int, Tuple[int, int]] = {}
        for pos, n in enumerate(out_leaves_per_pos):
            pos_slices[pos] = (idx, idx + n)
            for v in jaxpr.outvars[idx:idx + n]:
                if pos < len(spec.out_kinds):
                    out_kind[v] = spec.out_kinds[pos]
            idx += n
        arg_slices: Dict[int, Tuple[int, int]] = {}
        idx = 0
        for pos, leaves in enumerate(flat_per_arg):
            arg_slices[pos] = (idx, idx + len(leaves))
            idx += len(leaves)
        for out_pos, arg_pos in spec.alias_pairs:
            if out_pos not in pos_slices or arg_pos not in arg_slices:
                continue
            o0, o1 = pos_slices[out_pos]
            a0, a1 = arg_slices[arg_pos]
            if o1 - o0 != a1 - a0:
                continue
            for ov, iv in zip(jaxpr.outvars[o0:o1], jaxpr.invars[a0:a1]):
                out_alias[ov] = iv

    # ---- walk equations ----------------------------------------------
    tensors: Dict[str, TensorSpec] = {}
    operators: List[Operator] = []
    names: Dict[Any, str] = {}

    def name_of(v) -> str:
        if v not in names:
            names[v] = f"v{len(names)}"
        return names[v]

    outvar_set = set(jaxpr.outvars)
    grad_hint: set = set()

    for v in jaxpr.invars:
        tid = name_of(v)
        tensors[tid] = TensorSpec(
            tid=tid, size_bytes=_nbytes(v.aval), shape=tuple(v.aval.shape),
            dtype=str(v.aval.dtype), kind=invar_kind.get(v, TensorKind.INPUT),
            job_id=job_id)
    for v in jaxpr.constvars:
        tid = name_of(v)
        tensors[tid] = TensorSpec(
            tid=tid, size_bytes=_nbytes(v.aval), shape=tuple(v.aval.shape),
            dtype=str(v.aval.dtype), kind=TensorKind.INPUT, job_id=job_id)

    seen_opt_phase = False
    for idx, eqn in enumerate(jaxpr.eqns):
        in_ids = tuple(name_of(v) for v in eqn.invars
                       if isinstance(v, jcore.Var) and v in names)
        # brand-new invars (e.g. from literals) are ignored
        out_ids = []
        flops, bts = cost_model.eqn_cost(eqn)
        if phase_split is not None:
            phase = phase_split(eqn)
        else:
            phase = Phase.OPT if seen_opt_phase else Phase.FB
        for v in eqn.outvars:
            tid = name_of(v)
            out_ids.append(tid)
            alias = out_alias.get(v)
            kind = out_kind.get(
                v, TensorKind.OUTPUT if v in outvar_set else TensorKind.ACTIVATION)
            if alias is not None:
                kind = (TensorKind.PARAM
                        if invar_kind.get(alias) is TensorKind.PARAM
                        else TensorKind.OPT_STATE)
                seen_opt_phase = True
                phase = Phase.OPT
            tensors[tid] = TensorSpec(
                tid=tid, size_bytes=_nbytes(v.aval), shape=tuple(v.aval.shape),
                dtype=str(v.aval.dtype), kind=kind, job_id=job_id,
                updates=names.get(alias) if alias is not None else None)
        operators.append(Operator(
            idx=idx, name=str(eqn.primitive.name), inputs=in_ids,
            outputs=tuple(out_ids), flops=flops, bytes_accessed=bts,
            latency=cost_model.latency(flops, bts, eqn.primitive.name),
            phase=phase, job_id=job_id,
            params={"eqn_index": idx}))

    initial = [name_of(v) for v in list(jaxpr.invars) + list(jaxpr.constvars)]
    seq = AccessSequence(job_id, operators, tensors, initial_resident=initial)
    seq.params = {"n_eqns": len(jaxpr.eqns)}  # type: ignore[attr-defined]
    return seq, closed


def capture_train_step(fn: Callable, params: Any, opt_state: Any, batch: Any,
                       job_id: str = "job0",
                       cost_model: Optional[CostModel] = None,
                       experience=None):
    """Capture a canonical ``train_step(params, opt_state, batch) ->
    (new_params, new_opt_state, loss)``: params/opt-state kinds + the
    across-iteration aliasing the paper's Opt-phase scheduling needs."""
    spec = CaptureSpec(
        arg_kinds=[TensorKind.PARAM, TensorKind.OPT_STATE, TensorKind.INPUT],
        out_kinds=[TensorKind.PARAM, TensorKind.OPT_STATE, TensorKind.OUTPUT],
        alias_pairs=[(0, 0), (1, 1)])
    return capture(fn, params, opt_state, batch, job_id=job_id, spec=spec,
                   cost_model=cost_model, experience=experience)
