"""GPU memory peak analysis (paper §IV-B, Algorithm 2).

Sweeps the merged event timeline of one or more jobs — tensor accesses plus
already-scheduled swap events — and reports the memory footprint peak (MP),
the tensors resident at the peak (MPT), the last input access before the peak
(LUA) and the peak instant (MPTime).

Memory changes at exactly five situations (paper §IV-B):
  1. iteration beginning   — inputs + parameters not swapped out last iteration
  2. TGA                   — footprint increases (updated parameters alias the
                             old parameter's storage: no increase; the buffer
                             is reserved when the producing op launches)
  3. swap-in end           — footprint increases
  4. swap-out end          — footprint decreases (or at the end of the
                             overlapping TUA if that ends later)
  5. tensor release        — footprint decreases after the last access

Performance: the scheduler calls analyze() once per greedy iteration, so
base events (accesses + activity-analysis releases — O(10⁴) on real nets)
are cached per timeline version and merged with the handful of plan events
per call instead of being rebuilt and re-sorted every time.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

from .access import AccessSequence, AccessType, TensorKind, TensorSpec
from .plan import EventType, SchedulingPlan

# Tensor kinds that persist across iterations unless explicitly swapped out.
PERSISTENT_KINDS = (TensorKind.PARAM, TensorKind.OPT_STATE)


def storage_of(spec: TensorSpec) -> str:
    """Updated parameters reuse the old parameter's storage (paper §IV-B 2))."""
    return spec.updates if spec.updates is not None else spec.tid


@dataclasses.dataclass
class MemEvent:
    time: float
    delta: int               # signed bytes
    storage: str
    job_id: str
    kind: str                # "init" | "tga" | "swap_in" | "swap_out" | "release"
    order: int = 0           # tie-break: frees before allocs at equal time


@dataclasses.dataclass
class PeakReport:
    peak_bytes: int
    peak_time: float
    # (storage_id, job_id, size_bytes) resident at the peak, largest first
    peak_tensors: List[Tuple[str, str, int]]
    last_input_access: Dict[str, float]
    timeline: List[Tuple[float, int]]
    per_job_peak: Dict[str, int]

    def mpt_ids(self) -> List[str]:
        return [t[0] for t in self.peak_tensors]


# ----------------------------------------------------------------------
# Event construction (cached base + per-plan deltas)
# ----------------------------------------------------------------------
class _JobBase:
    """Timeline-version-keyed per-job static data."""

    def __init__(self, seq: AccessSequence, free_at_last_use: bool):
        self.sizes: Dict[str, int] = {}
        for spec in seq.tensors.values():
            st = storage_of(spec)
            self.sizes[st] = max(self.sizes.get(st, 0), spec.size_bytes)

        self.persistent: set = set()
        last_end: Dict[str, float] = {}
        for tid, accs in seq.accesses_by_tensor.items():
            spec = seq.tensors[tid]
            st = storage_of(spec)
            last_end[st] = max(last_end.get(st, 0.0),
                               max(a.end_time for a in accs))
            if spec.kind in PERSISTENT_KINDS or spec.updates is not None:
                self.persistent.add(st)
        self.last_end = last_end

        fixed: List[MemEvent] = []
        seen_init = set()
        for tid in seq.initial_resident:
            spec = seq.tensors.get(tid)
            if spec is None:
                continue
            st = storage_of(spec)
            if st in seen_init:
                continue
            seen_init.add(st)
            fixed.append(MemEvent(0.0, +self.sizes[st], st, seq.job_id,
                                  "init", order=0))
        alloc_seen = set(seen_init)
        for a in seq.accesses:
            if a.access_type is not AccessType.TGA:
                continue
            spec = seq.tensors[a.tensor_id]
            st = storage_of(spec)
            if spec.updates is not None or st in alloc_seen:
                continue
            alloc_seen.add(st)
            alloc_t = seq.op_start[a.op_idx] \
                if 0 <= a.op_idx < len(seq.op_start) else a.time
            fixed.append(MemEvent(alloc_t, +self.sizes[st], st, seq.job_id,
                                  "tga", order=1))
        fixed.sort(key=_ekey)
        self.fixed = fixed

        rel: List[MemEvent] = []
        for st, t_end in last_end.items():
            if st in self.persistent:
                continue
            t = t_end if free_at_last_use else seq.iteration_time
            rel.append(MemEvent(t, -self.sizes[st], st, seq.job_id,
                                "release", order=-1))
        rel.sort(key=_ekey)
        self.releases = rel

        tuas = sorted((a.time for a in seq.accesses
                       if a.access_type is AccessType.TUA))
        self.tua_times = tuas


def _ekey(e: MemEvent):
    return (e.time, e.order)


_BASE_CACHE: Dict[Tuple[int, int, bool], _JobBase] = {}


def _job_base(seq: AccessSequence, free_at_last_use: bool) -> _JobBase:
    key = (getattr(seq, "serial", id(seq)),
           getattr(seq, "_timeline_version", 0), free_at_last_use)
    hit = _BASE_CACHE.get(key)
    if hit is None:
        if len(_BASE_CACHE) > 256:
            _BASE_CACHE.clear()
        hit = _JobBase(seq, free_at_last_use)
        _BASE_CACHE[key] = hit
    return hit


def _plan_events(seq: AccessSequence, plan: SchedulingPlan,
                 base: _JobBase) -> Tuple[List[MemEvent], set]:
    """Dynamic events from a plan + the storages whose base release is
    superseded (swapped-out or override-released)."""
    events: List[MemEvent] = []
    touched: set = set()
    sizes = base.sizes
    for ev in plan.events:
        spec = seq.tensors.get(ev.tensor_id)
        if spec is None:
            continue
        st = storage_of(spec)
        if ev.event_type is EventType.SWAP_OUT:
            free_t = ev.end
            for a in seq.tensor_accesses(ev.tensor_id):
                if a.time <= ev.end and a.end_time > free_t:
                    free_t = a.end_time
            touched.add(st)
            events.append(MemEvent(free_t, -sizes[st], st, seq.job_id,
                                   "swap_out", order=-1))
        elif ev.event_type in (EventType.SWAP_IN, EventType.RECOMPUTE):
            events.append(MemEvent(ev.end, +sizes[st], st, seq.job_id,
                                   "swap_in", order=1))
        elif ev.event_type is EventType.RELEASE:
            events.append(MemEvent(ev.end, -sizes[st], st, seq.job_id,
                                   "release", order=-1))
    for tid, op_idx in plan.release_after_op.items():
        spec = seq.tensors.get(tid)
        if spec is None or not (0 <= op_idx < len(seq.op_end)):
            continue
        st = storage_of(spec)
        t = min(base.last_end.get(st, float("inf")), seq.op_end[op_idx])
        touched.add(st)
        events.append(MemEvent(t, -sizes[st], st, seq.job_id,
                               "release", order=-1))
    events.sort(key=_ekey)
    return events, touched


def _offset_iter(events: Iterable[MemEvent], offset: float
                 ) -> Iterator[MemEvent]:
    if not offset:
        yield from events
        return
    for e in events:
        yield dataclasses.replace(e, time=e.time + offset)


def build_events(seq: AccessSequence,
                 plan: Optional[SchedulingPlan] = None,
                 offset: float = 0.0,
                 free_at_last_use: bool = True) -> List[MemEvent]:
    """All memory events for one job (compat API; used by tests)."""
    base = _JobBase(seq, free_at_last_use)
    dyn, touched = (_plan_events(seq, plan, base) if plan is not None
                    else ([], set()))
    evs = list(base.fixed) \
        + [e for e in base.releases if e.storage not in touched] + dyn
    if offset:
        evs = [dataclasses.replace(e, time=e.time + offset) for e in evs]
    return evs


def analyze(seqs: Sequence[AccessSequence],
            plans: Optional[Dict[str, SchedulingPlan]] = None,
            offsets: Optional[Dict[str, float]] = None,
            window: Optional[Tuple[float, float]] = None,
            free_at_last_use: bool = True) -> PeakReport:
    """Algorithm 2 over the merged timeline of several jobs.

    `offsets[job_id]` shifts a job's timeline (jobs run asynchronously).
    `window` restricts peak detection to [lo, hi).
    """
    plans = plans or {}
    offsets = offsets or {}
    streams = []
    tuas: List[Tuple[float, str]] = []
    for seq in seqs:
        off = offsets.get(seq.job_id, 0.0)
        base = _job_base(seq, free_at_last_use)
        plan = plans.get(seq.job_id)
        if plan is not None and (plan.events or plan.release_after_op):
            dyn, touched = _plan_events(seq, plan, base)
        else:
            dyn, touched = [], set()
        streams.append(_offset_iter(base.fixed, off))
        if touched:
            streams.append(_offset_iter(
                (e for e in base.releases if e.storage not in touched), off))
        else:
            streams.append(_offset_iter(base.releases, off))
        if dyn:
            streams.append(_offset_iter(dyn, off))
        tuas.extend((t + off, seq.job_id) for t in base.tua_times)
    events = list(heapq.merge(*streams, key=_ekey))
    tuas.sort()

    # --- pass 1: find the peak index (no snapshots: snapshotting/sorting
    # the resident set at every running peak was O(n²) and dominated the
    # scheduler's runtime on DenseNet-scale graphs) -----------------------
    resident: Dict[Tuple[str, str], int] = {}
    mem = 0
    peak, peak_time, peak_idx = 0, 0.0, -1
    timeline: List[Tuple[float, int]] = []
    per_job: Dict[str, int] = {}
    job_mem: Dict[str, int] = {}

    for i, ev in enumerate(events):
        key = (ev.job_id, ev.storage)
        if ev.delta > 0:
            if key in resident:
                continue  # already resident (idempotent alloc)
            resident[key] = ev.delta
            mem += ev.delta
            jm = job_mem.get(ev.job_id, 0) + ev.delta
            job_mem[ev.job_id] = jm
            if jm > per_job.get(ev.job_id, 0):
                per_job[ev.job_id] = jm
        else:
            if key not in resident:
                continue  # already freed (idempotent free)
            sz = resident.pop(key)
            mem -= sz
            job_mem[ev.job_id] = job_mem.get(ev.job_id, 0) - sz
        timeline.append((ev.time, mem))
        in_window = window is None or (window[0] <= ev.time < window[1])
        if in_window and mem > peak:
            peak, peak_time, peak_idx = mem, ev.time, i

    # --- pass 2: replay to the peak index, reconstruct MPT + LUA once ----
    resident.clear()
    for ev in events[:peak_idx + 1]:
        key = (ev.job_id, ev.storage)
        if ev.delta > 0:
            resident.setdefault(key, ev.delta)
        else:
            resident.pop(key, None)
    peak_resident = sorted(
        ((st, j, sz) for (j, st), sz in resident.items()),
        key=lambda x: -x[2])
    lua: Dict[str, float] = {s.job_id: 0.0 for s in seqs}
    for t, j in tuas:
        if t > peak_time:
            break
        lua[j] = t
    return PeakReport(peak_bytes=peak, peak_time=peak_time,
                      peak_tensors=peak_resident, last_input_access=lua,
                      timeline=timeline, per_job_peak=per_job)


def vanilla_peak(seq: AccessSequence, free_at_last_use: bool = False) -> int:
    """Peak with no scheduling at all — the paper's vanilla group (VMP):
    on the paper's platform nothing is freed until the iteration ends."""
    return analyze([seq], free_at_last_use=free_at_last_use).peak_bytes


def unroll(seq: AccessSequence, n_iters: int = 2) -> AccessSequence:
    """Unroll `n_iters` iterations of a job into one sequence.

    Persistent tensors (params, optimizer state, and updated-parameter
    aliases) keep their identity across iterations; activations, gradients
    and inputs become per-iteration instances (``tid~k``).
    """
    from .access import Operator  # local import to avoid cycles

    def persists(spec: TensorSpec) -> bool:
        return spec.kind in PERSISTENT_KINDS or spec.updates is not None

    ops: List[Operator] = []
    tensors: Dict[str, TensorSpec] = {}
    n_ops = len(seq.operators)

    def rename(tid: str, k: int) -> str:
        spec = seq.tensors.get(tid)
        if spec is None or persists(spec):
            return tid
        return f"{tid}~{k}"

    for k in range(n_iters):
        for op in seq.operators:
            ops.append(Operator(
                idx=k * n_ops + op.idx, name=op.name,
                inputs=tuple(rename(t, k) for t in op.inputs),
                outputs=tuple(rename(t, k) for t in op.outputs),
                latency=op.latency, flops=op.flops,
                bytes_accessed=op.bytes_accessed, phase=op.phase,
                params=op.params, job_id=op.job_id))
        for tid, spec in seq.tensors.items():
            new_id = rename(tid, k)
            if new_id in tensors:
                continue
            tensors[new_id] = dataclasses.replace(spec, tid=new_id)
    initial = [rename(t, 0) for t in seq.initial_resident]
    out = AccessSequence(seq.job_id, ops, tensors, initial_resident=initial)
    return out
