"""GPU memory peak analysis (paper §IV-B, Algorithm 2).

Sweeps the merged event timeline of one or more jobs — tensor accesses plus
already-scheduled swap events — and reports the memory footprint peak (MP),
the tensors resident at the peak (MPT), the last input access before the peak
(LUA) and the peak instant (MPTime).

Memory changes at exactly five situations (paper §IV-B):
  1. iteration beginning   — inputs + parameters not swapped out last iteration
  2. TGA                   — footprint increases (updated parameters alias the
                             old parameter's storage: no increase; the buffer
                             is reserved when the producing op launches)
  3. swap-in end           — footprint increases
  4. swap-out end          — footprint decreases (or at the end of the
                             overlapping TUA if that ends later)
  5. tensor release        — footprint decreases after the last access

Performance: the scheduler calls analyze() once per greedy iteration, so
the sweep is vectorized end to end.  Base events (accesses +
activity-analysis releases — O(10⁴) on real nets) are cached per timeline
version as structure-of-arrays buffers (times / tie-orders / signed deltas
/ storage-key ids), PRE-SORTED by (time, order); per call the handful of
plan events is merged into the sorted buffers by binary search, residency
comes from a cumulative sum over "effective" events (a per-key sign-change
mask reproduces the idempotent alloc/free semantics exactly), and the peak
/ MPT / LUA fall out of argmax + scatter operations.  The per-event
implementation is kept verbatim as ``_reference_sweep`` — the equivalence
tests assert the vectorized path is byte-identical to it, which is what
keeps the golden seed plans stable.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .access import AccessSequence, AccessType, TensorKind, TensorSpec
from .plan import EventType, SchedulingPlan

# Tensor kinds that persist across iterations unless explicitly swapped out.
PERSISTENT_KINDS = (TensorKind.PARAM, TensorKind.OPT_STATE)


def storage_of(spec: TensorSpec) -> str:
    """Updated parameters reuse the old parameter's storage (paper §IV-B 2))."""
    return spec.updates if spec.updates is not None else spec.tid


@dataclasses.dataclass
class MemEvent:
    time: float
    delta: int               # signed bytes
    storage: str
    job_id: str
    kind: str                # "init" | "tga" | "swap_in" | "swap_out" | "release"
    order: int = 0           # tie-break: frees before allocs at equal time


class PeakReport:
    """Algorithm-2 output.  ``peak_tensors`` (the MPT: (storage_id,
    job_id, size_bytes) resident at the peak, largest first) and
    ``timeline`` may be handed in as thunks and are then materialized on
    first attribute access — replans run full-iteration sweeps whose MPT
    and timeline nobody reads, and at 100k ops building those Python
    lists dominates the sweep itself."""

    def __init__(self, peak_bytes: int, peak_time: float,
                 peak_tensors: Optional[List[Tuple[str, str, int]]] = None,
                 last_input_access: Optional[Dict[str, float]] = None,
                 timeline: Optional[List[Tuple[float, int]]] = None,
                 per_job_peak: Optional[Dict[str, int]] = None,
                 peak_tensors_fn=None, timeline_fn=None):
        self.peak_bytes = peak_bytes
        self.peak_time = peak_time
        self.last_input_access = last_input_access or {}
        self.per_job_peak = per_job_peak or {}
        self._peak_tensors = peak_tensors
        self._peak_tensors_fn = peak_tensors_fn
        self._timeline = timeline
        self._timeline_fn = timeline_fn

    @property
    def peak_tensors(self) -> List[Tuple[str, str, int]]:
        if self._peak_tensors is None:
            self._peak_tensors = (self._peak_tensors_fn()
                                  if self._peak_tensors_fn else [])
            self._peak_tensors_fn = None
        return self._peak_tensors

    @property
    def timeline(self) -> List[Tuple[float, int]]:
        if self._timeline is None:
            self._timeline = self._timeline_fn() if self._timeline_fn else []
            self._timeline_fn = None
        return self._timeline

    def mpt_ids(self) -> List[str]:
        return [t[0] for t in self.peak_tensors]

    def __repr__(self) -> str:  # pragma: no cover
        return (f"PeakReport(peak_bytes={self.peak_bytes}, "
                f"peak_time={self.peak_time}, "
                f"per_job_peak={self.per_job_peak})")


# ----------------------------------------------------------------------
# Event construction (cached base + per-plan deltas)
# ----------------------------------------------------------------------
class _JobBase:
    """Timeline-version-keyed per-job static data."""

    def __init__(self, seq: AccessSequence, free_at_last_use: bool):
        self.sizes: Dict[str, int] = {}
        for spec in seq.tensors.values():
            st = storage_of(spec)
            self.sizes[st] = max(self.sizes.get(st, 0), spec.size_bytes)

        self.persistent: set = set()
        last_end: Dict[str, float] = {}
        for tid, accs in seq.accesses_by_tensor.items():
            spec = seq.tensors[tid]
            st = storage_of(spec)
            last_end[st] = max(last_end.get(st, 0.0),
                               max(a.end_time for a in accs))
            if spec.kind in PERSISTENT_KINDS or spec.updates is not None:
                self.persistent.add(st)
        self.last_end = last_end

        fixed: List[MemEvent] = []
        seen_init = set()
        for tid in seq.initial_resident:
            spec = seq.tensors.get(tid)
            if spec is None:
                continue
            st = storage_of(spec)
            if st in seen_init:
                continue
            seen_init.add(st)
            fixed.append(MemEvent(0.0, +self.sizes[st], st, seq.job_id,
                                  "init", order=0))
        alloc_seen = set(seen_init)
        for a in seq.accesses:
            if a.access_type is not AccessType.TGA:
                continue
            spec = seq.tensors[a.tensor_id]
            st = storage_of(spec)
            if spec.updates is not None or st in alloc_seen:
                continue
            alloc_seen.add(st)
            alloc_t = seq.op_start[a.op_idx] \
                if 0 <= a.op_idx < len(seq.op_start) else a.time
            fixed.append(MemEvent(alloc_t, +self.sizes[st], st, seq.job_id,
                                  "tga", order=1))
        fixed.sort(key=_ekey)
        self.fixed = fixed

        rel: List[MemEvent] = []
        for st, t_end in last_end.items():
            if st in self.persistent:
                continue
            t = t_end if free_at_last_use else seq.iteration_time
            rel.append(MemEvent(t, -self.sizes[st], st, seq.job_id,
                                "release", order=-1))
        rel.sort(key=_ekey)
        self.releases = rel

        tuas = sorted((a.time for a in seq.accesses
                       if a.access_type is AccessType.TUA))
        self.tua_times = tuas
        self.tua_arr = np.asarray(tuas, dtype=np.float64)

        # ---- structure-of-arrays mirror of fixed+releases, pre-sorted ----
        # Local key ids index `key_names`/`key_sizes`; the merged-sort order
        # reproduces heapq.merge([fixed, releases]) exactly: stable lexsort
        # of the concatenation keeps fixed before releases at equal
        # (time, order), matching stream priority.
        self.key_index: Dict[str, int] = {}
        self.key_names: List[str] = []
        for st in self.sizes:
            self.key_index[st] = len(self.key_names)
            self.key_names.append(st)
        self.key_sizes = np.asarray(
            [self.sizes[st] for st in self.key_names], dtype=np.int64)
        evs = list(fixed) + list(rel)
        t = np.asarray([e.time for e in evs], dtype=np.float64)
        o = np.asarray([e.order for e in evs], dtype=np.int64)
        d = np.asarray([e.delta for e in evs], dtype=np.int64)
        k = np.asarray([self.key_index[e.storage] for e in evs],
                       dtype=np.int64)
        is_rel = np.zeros(len(evs), dtype=bool)
        is_rel[len(fixed):] = True
        order = np.lexsort((o, t)) if len(evs) else np.empty(0, np.int64)
        self.arr_t = t[order]
        self.arr_o = o[order]
        self.arr_d = d[order]
        self.arr_k = k[order]
        self.arr_is_rel = is_rel[order]


def _ekey(e: MemEvent):
    return (e.time, e.order)


_BASE_CACHE: Dict[Tuple[int, int, bool], _JobBase] = {}

# whole-report memo (see analyze): a report's lazy thunks pin the sweep's
# event arrays, so the LRU is deliberately tiny — it only needs to cover
# the replan pattern of re-analyzing an unchanged (seqs, plans) pair
_REPORT_CACHE: "collections.OrderedDict[tuple, PeakReport]" = \
    collections.OrderedDict()
_REPORT_CACHE_CAP = 4


def _report_cache_put(ck: Optional[tuple], rep: PeakReport) -> PeakReport:
    if ck is not None:
        while len(_REPORT_CACHE) >= _REPORT_CACHE_CAP:
            _REPORT_CACHE.popitem(last=False)
        _REPORT_CACHE[ck] = rep
    return rep


def _job_base(seq: AccessSequence, free_at_last_use: bool) -> _JobBase:
    key = (getattr(seq, "serial", id(seq)),
           getattr(seq, "_timeline_version", 0), free_at_last_use)
    hit = _BASE_CACHE.get(key)
    if hit is None:
        if len(_BASE_CACHE) > 256:
            _BASE_CACHE.clear()
        hit = _JobBase(seq, free_at_last_use)
        _BASE_CACHE[key] = hit
    return hit


def _schedule_event_list(seq: AccessSequence, base: _JobBase,
                         sched_events) -> Tuple[List[MemEvent], set]:
    """MemEvents for a list of ScheduleEvents (unsorted), plus the storages
    whose base release a swap-out supersedes."""
    events: List[MemEvent] = []
    touched: set = set()
    sizes = base.sizes
    for ev in sched_events:
        spec = seq.tensors.get(ev.tensor_id)
        if spec is None:
            continue
        st = storage_of(spec)
        if ev.event_type is EventType.SWAP_OUT:
            free_t = ev.end
            for a in seq.tensor_accesses(ev.tensor_id):
                if a.time <= ev.end and a.end_time > free_t:
                    free_t = a.end_time
            touched.add(st)
            events.append(MemEvent(free_t, -sizes[st], st, seq.job_id,
                                   "swap_out", order=-1))
        elif ev.event_type in (EventType.SWAP_IN, EventType.RECOMPUTE):
            events.append(MemEvent(ev.end, +sizes[st], st, seq.job_id,
                                   "swap_in", order=1))
        elif ev.event_type is EventType.RELEASE:
            events.append(MemEvent(ev.end, -sizes[st], st, seq.job_id,
                                   "release", order=-1))
    return events, touched


def _plan_events(seq: AccessSequence, plan: SchedulingPlan,
                 base: _JobBase) -> Tuple[List[MemEvent], set]:
    """Dynamic events from a plan + the storages whose base release is
    superseded (swapped-out or override-released)."""
    events, touched = _schedule_event_list(seq, base, plan.events)
    sizes = base.sizes
    for tid, op_idx in plan.release_after_op.items():
        spec = seq.tensors.get(tid)
        if spec is None or not (0 <= op_idx < len(seq.op_end)):
            continue
        st = storage_of(spec)
        t = min(base.last_end.get(st, float("inf")), seq.op_end[op_idx])
        touched.add(st)
        events.append(MemEvent(t, -sizes[st], st, seq.job_id,
                               "release", order=-1))
    events.sort(key=_ekey)
    return events, touched


# ----------------------------------------------------------------------
# Vectorized structure-of-arrays sweep
# ----------------------------------------------------------------------
def _events_to_arrays(evs: List[MemEvent], base: _JobBase):
    """SoA buffers for a (sorted) MemEvent list, local key ids."""
    t = np.asarray([e.time for e in evs], dtype=np.float64)
    o = np.asarray([e.order for e in evs], dtype=np.int64)
    d = np.asarray([e.delta for e in evs], dtype=np.int64)
    k = np.asarray([base.key_index[e.storage] for e in evs], dtype=np.int64)
    return t, o, d, k


def _insert_positions(bt: np.ndarray, bo: np.ndarray,
                      dt: np.ndarray, do: np.ndarray) -> np.ndarray:
    """For each (time, order)-sorted dyn event, the number of base events
    with key <= its key — i.e. the np.insert position that lands dyn
    events AFTER equal-key base events (heapq.merge stream priority:
    [fixed, releases, dyn])."""
    lo = np.searchsorted(bt, dt, side="left")
    hi = np.searchsorted(bt, dt, side="right")
    pos = lo.copy()
    for j in np.flatnonzero(hi > lo):
        a, b = int(lo[j]), int(hi[j])
        pos[j] = a + int(np.searchsorted(bo[a:b], do[j], side="right"))
    return pos


def _merge_seq_arrays(base: _JobBase, dyn: List[MemEvent],
                      filt: Optional[set]):
    """One job's merged (time, order)-sorted event buffers:
    (times, orders, deltas, local key ids, is_base_release).

    Byte-order-identical to ``heapq.merge`` over the reference streams
    [fixed, releases-filtered-by-``filt``, dyn]: the cached base buffers
    are pre-sorted with fixed-before-releases tie priority, and dyn events
    are binary-search inserted after equal-(time, order) base rows."""
    bt, bo, bd, bk = base.arr_t, base.arr_o, base.arr_d, base.arr_k
    brel = base.arr_is_rel
    if filt:
        present = [base.key_index[st] for st in filt
                   if st in base.key_index]
        if present:
            tbl = np.zeros(len(base.key_names), dtype=bool)
            tbl[present] = True
            keep = ~(brel & tbl[bk])
            bt, bo, bd, bk, brel = (bt[keep], bo[keep], bd[keep], bk[keep],
                                    brel[keep])
    if not dyn:
        return bt, bo, bd, bk, brel
    dt, do, dd, dk = _events_to_arrays(dyn, base)
    pos = _insert_positions(bt, bo, dt, do)
    return (np.insert(bt, pos, dt), np.insert(bo, pos, do),
            np.insert(bd, pos, dd), np.insert(bk, pos, dk),
            np.insert(brel, pos, False))


def _seq_arrays(seq: AccessSequence, plan: Optional[SchedulingPlan],
                free_at_last_use: bool):
    """Single-job merged event buffers with the job's OWN touched-release
    filter (the semantics ``build_events`` / ``find_safe_points`` use)."""
    base = _job_base(seq, free_at_last_use)
    if plan is not None and (plan.events or plan.release_after_op):
        dyn, touched = _plan_events(seq, plan, base)
    else:
        dyn, touched = [], set()
    t, o, d, k, brel = _merge_seq_arrays(base, dyn, touched or None)
    return t, o, d, k, brel, base


def _effective_mask(k: np.ndarray, d: np.ndarray,
                    init_sign: Optional[np.ndarray] = None,
                    n_keys: int = 0) -> np.ndarray:
    """The idempotent alloc/free semantics as a per-key sign-change mask.

    State after ANY event equals (delta > 0) — an alloc is effective iff
    the key was not resident, a free iff it was — so an event is effective
    exactly when its sign differs from the key's previous event's sign
    (initially: from ``init_sign``, default not-resident)."""
    n = len(k)
    if n == 0:
        return np.zeros(0, dtype=bool)
    sign = d > 0
    g = np.argsort(k, kind="stable")
    gk, gs = k[g], sign[g]
    first = np.empty(n, dtype=bool)
    first[0] = True
    first[1:] = gk[1:] != gk[:-1]
    prev = np.empty(n, dtype=bool)
    prev[1:] = gs[:-1]
    if init_sign is None:
        prev[first] = False
    else:
        prev[first] = init_sign[gk[first]]
    eff = np.empty(n, dtype=bool)
    eff[g] = gs != prev
    return eff


def _offset_iter(events: Iterable[MemEvent], offset: float
                 ) -> Iterator[MemEvent]:
    if not offset:
        yield from events
        return
    for e in events:
        yield dataclasses.replace(e, time=e.time + offset)


def build_events(seq: AccessSequence,
                 plan: Optional[SchedulingPlan] = None,
                 offset: float = 0.0,
                 free_at_last_use: bool = True) -> List[MemEvent]:
    """All memory events for one job (compat API; used by tests)."""
    base = _JobBase(seq, free_at_last_use)
    dyn, touched = (_plan_events(seq, plan, base) if plan is not None
                    else ([], set()))
    evs = list(base.fixed) \
        + [e for e in base.releases if e.storage not in touched] + dyn
    if offset:
        evs = [dataclasses.replace(e, time=e.time + offset) for e in evs]
    return evs


def analyze(seqs: Sequence[AccessSequence],
            plans: Optional[Dict[str, SchedulingPlan]] = None,
            offsets: Optional[Dict[str, float]] = None,
            window: Optional[Tuple[float, float]] = None,
            free_at_last_use: bool = True) -> PeakReport:
    """Algorithm 2 over the merged timeline of several jobs.

    `offsets[job_id]` shifts a job's timeline (jobs run asynchronously).
    `window` restricts peak detection to [lo, hi).

    Vectorized numpy sweep over structure-of-arrays event buffers;
    byte-identical to the per-event ``_reference_sweep`` (the equivalence
    tests pin this, which is what keeps golden plans stable).

    Whole reports are memoized on (sequence serial + timeline version,
    plan uid + version, offset, window, semantics): the incremental-replan
    path re-analyzes the same prior plans on every call, and plan
    mutations are visible through the plan's monotone version counter.
    """
    plans = plans or {}
    offsets = offsets or {}
    ck: Optional[tuple] = None
    if all(getattr(s, "serial", None) is not None for s in seqs):
        ck = (free_at_last_use, window) + tuple(
            (s.serial, s._timeline_version, offsets.get(s.job_id, 0.0),
             ((p.uid, p.version) if (p := plans.get(s.job_id)) is not None
              else None))
            for s in seqs)
        hit = _REPORT_CACHE.get(ck)
        if hit is not None:
            _REPORT_CACHE.move_to_end(ck)
            return hit

    # ---- merged SoA buffers (global key id = (job slot, storage)) ------
    parts = []          # per-seq (t, o, d, gk, seq_idx array)
    key_names: List[str] = []
    key_jobs: List[str] = []
    key_size_parts: List[np.ndarray] = []
    dup_jobs = len({s.job_id for s in seqs}) != len(list(seqs))
    gid_by_job: Dict[str, Dict[str, int]] = {}
    bases = []
    # Phase A: per-seq dyn events + touched sets.  The reference merge
    # builds its release-filter as a generator expression closing over the
    # loop variable `touched` and only consumes it AFTER the loop, so every
    # seq whose own touched set was non-empty is actually filtered by the
    # LAST seq's touched set.  Golden plans pin that behaviour, so the
    # vectorized sweep reproduces it here (single-seq calls are
    # unaffected: own == last).
    pre = []
    for seq in seqs:
        base = _job_base(seq, free_at_last_use)
        plan = plans.get(seq.job_id)
        if plan is not None and (plan.events or plan.release_after_op):
            dyn, touched = _plan_events(seq, plan, base)
        else:
            dyn, touched = [], set()
        pre.append((seq, base, dyn, touched))
    touched_last = pre[-1][3] if pre else set()
    for si, (seq, base, dyn, touched) in enumerate(pre):
        off = offsets.get(seq.job_id, 0.0)
        t, o, d, k, _rel = _merge_seq_arrays(
            base, dyn, touched_last if touched else None)
        bases.append((seq, off, base))
        if dup_jobs:
            # two seqs sharing a job_id share (job, storage) key identity,
            # exactly like the reference's resident dict
            jmap = gid_by_job.setdefault(seq.job_id, {})
            remap = np.empty(len(base.key_names), dtype=np.int64)
            for li, st in enumerate(base.key_names):
                gid = jmap.get(st)
                if gid is None:
                    gid = jmap[st] = len(key_names)
                    key_names.append(st)
                    key_jobs.append(seq.job_id)
                    key_size_parts.append(base.key_sizes[li:li + 1])
                remap[li] = gid
            gk = remap[k]
        else:
            base_off = len(key_names)
            key_names.extend(base.key_names)
            key_jobs.extend([seq.job_id] * len(base.key_names))
            key_size_parts.append(base.key_sizes)
            gk = k + base_off
        tt = t + off if off else t
        parts.append((tt, o, d, gk,
                      np.full(len(t), si, dtype=np.int64)))

    if not parts or sum(len(p[0]) for p in parts) == 0:
        return _report_cache_put(ck, PeakReport(
            peak_bytes=0, peak_time=0.0, peak_tensors=[],
            last_input_access={s.job_id: 0.0 for s in seqs},
            timeline=[], per_job_peak={}))
    if len(parts) == 1:
        t, o, d, gk, sx = parts[0]     # single job: already sorted
    else:
        t = np.concatenate([p[0] for p in parts])
        o = np.concatenate([p[1] for p in parts])
        d = np.concatenate([p[2] for p in parts])
        gk = np.concatenate([p[3] for p in parts])
        sx = np.concatenate([p[4] for p in parts])
        srt = np.lexsort((o, t))       # stable: ties keep stream order
        t, o, d, gk, sx = t[srt], o[srt], d[srt], gk[srt], sx[srt]
    key_sizes_g = (np.concatenate(key_size_parts) if key_size_parts
                   else np.empty(0, np.int64))

    # ---- pass 1: effective events, residency cumsum, windowed peak -----
    eff = _effective_mask(gk, d)
    mem = np.cumsum(np.where(eff, d, 0))
    sign = d > 0
    if window is None:
        cand = eff
    else:
        cand = eff & (t >= window[0]) & (t < window[1])
    peak, peak_time, peak_idx = 0, 0.0, -1
    ci = np.flatnonzero(cand)
    if len(ci):
        cm = mem[ci]
        pmax = int(cm.max())
        if pmax > 0:              # strict `mem > peak` with peak starting 0
            j = int(ci[int(np.argmax(cm))])   # first occurrence of the max
            peak, peak_time, peak_idx = pmax, float(t[j]), j
    def timeline_fn(t=t, eff=eff, mem=mem):
        return list(zip(t[eff].tolist(), mem[eff].tolist()))

    # ---- per-job running peaks (updated at effective allocs only) ------
    per_job: Dict[str, int] = {}
    seq_list = list(seqs)
    seen_jobs: Dict[str, List[int]] = {}
    for si, seq in enumerate(seq_list):
        seen_jobs.setdefault(seq.job_id, []).append(si)
    for job_id, sis in seen_jobs.items():
        jmask = np.isin(sx, sis) if len(sis) > 1 else (sx == sis[0])
        am = jmask & eff & sign
        if am.any():
            jm = np.cumsum(np.where(jmask & eff, d, 0))
            per_job[job_id] = int(jm[am].max())

    # ---- pass 2: MPT at the peak index + LUA ---------------------------
    def peak_tensors_fn(gk=gk, sign=sign, eff=eff, peak_idx=peak_idx,
                        key_names=key_names, key_jobs=key_jobs,
                        key_sizes_g=key_sizes_g):
        if peak_idx < 0:
            return []
        P = peak_idx + 1
        kk, ss = gk[:P], sign[:P]
        last_sign = np.zeros(len(key_names), dtype=bool)
        last_sign[kk] = ss                       # last assignment wins
        ap = np.full(len(key_names), -1, dtype=np.int64)
        ii = np.flatnonzero(eff[:P] & ss)
        ap[kk[ii]] = ii                          # last effective alloc pos
        res = np.flatnonzero(last_sign)
        res = res[np.argsort(ap[res], kind="stable")]   # dict insert order
        res = res[np.argsort(-key_sizes_g[res], kind="stable")]
        return [(key_names[i], key_jobs[i], int(key_sizes_g[i]))
                for i in res.tolist()]

    lua: Dict[str, float] = {s.job_id: 0.0 for s in seqs}
    lua_found: set = set()
    for seq, off, base in bases:
        shifted = base.tua_arr + off if off else base.tua_arr
        i = int(np.searchsorted(shifted, peak_time, side="right"))
        if i:
            v = float(shifted[i - 1])
            lua[seq.job_id] = (max(lua[seq.job_id], v)
                               if seq.job_id in lua_found else v)
            lua_found.add(seq.job_id)
    return _report_cache_put(ck, PeakReport(
        peak_bytes=peak, peak_time=peak_time,
        peak_tensors_fn=peak_tensors_fn, last_input_access=lua,
        timeline_fn=timeline_fn, per_job_peak=per_job))


class WindowSweep:
    """Incremental windowed Algorithm-2 sweep for one job.

    ``PreemptiveReplanPass`` re-analyzes the remainder window
    ``[t_safe, T)`` after every candidate swap/recompute action.  Every
    event such an action adds starts at or after ``t_safe`` (the
    SwapPlanner's ``not_before`` pin), so the merged timeline's prefix
    before ``t_safe`` is invariant across steps: this class freezes the
    prefix aggregates once — per-key residency signs, running byte sum,
    effective-event timeline, MPT scatter state — and re-sweeps only the
    suffix rows per call.  Any precondition break (sequence timeline
    rebuilt, different window start, a prefix dyn event changed, a newly
    touched storage whose base release lies in the prefix) triggers a
    transparent re-freeze, so the result equals a full single-job
    ``analyze`` call byte-for-byte (the equivalence tests pin this).
    """

    def __init__(self, free_at_last_use: bool = True):
        self.falu = free_at_last_use
        self._frozen: Optional[dict] = None

    # -- prefix freeze -------------------------------------------------
    def _freeze(self, base: _JobBase, dyn: List[MemEvent], touched: set,
                dyn_pre: List[MemEvent], t0: float) -> dict:
        t, o, d, k, _rel = _merge_seq_arrays(base, dyn, touched or None)
        cut = int(np.searchsorted(t, t0, side="left"))
        n_keys = len(base.key_names)
        kp, dp = k[:cut], d[:cut]
        eff = _effective_mask(kp, dp)
        sign = dp > 0
        memcum = np.cumsum(np.where(eff, dp, 0))
        resident = np.zeros(n_keys, dtype=bool)
        resident[kp] = sign                     # last assignment wins
        ap = np.full(n_keys, -1, dtype=np.int64)
        ii = np.flatnonzero(eff & sign)
        ap[kp[ii]] = ii
        am = eff & sign
        rel_pre = base.arr_is_rel & (base.arr_t < t0)
        bidx = int(np.searchsorted(base.arr_t, t0, side="left"))
        self._frozen = {
            "base": base, "t0": t0,
            "dyn_pre": dyn_pre, "touched": set(touched),
            "cut": cut, "mem0": int(memcum[-1]) if cut else 0,
            "timeline": list(zip(t[:cut][eff].tolist(),
                                 memcum[eff].tolist())),
            "resident": resident, "ap": ap,
            "pj_max": int(memcum[am].max()) if am.any() else None,
            # storages whose base release sits in the prefix: a touched-set
            # change involving one of these rewrites the prefix
            "rel_pre": {base.key_names[i]
                        for i in np.unique(base.arr_k[rel_pre]).tolist()},
            # unfiltered base suffix rows (filtered per call)
            "bsuf": (base.arr_t[bidx:], base.arr_o[bidx:],
                     base.arr_d[bidx:], base.arr_k[bidx:],
                     base.arr_is_rel[bidx:]),
        }
        return self._frozen

    # -- per-call sweep ------------------------------------------------
    def report(self, seq: AccessSequence, plan: Optional[SchedulingPlan],
               t0: float, hi: float) -> PeakReport:
        base = _job_base(seq, self.falu)
        if plan is not None and (plan.events or plan.release_after_op):
            dyn, touched = _plan_events(seq, plan, base)
        else:
            dyn, touched = [], set()
        ncut = 0
        while ncut < len(dyn) and dyn[ncut].time < t0:
            ncut += 1
        dyn_pre, dyn_suf = dyn[:ncut], dyn[ncut:]
        fz = self._frozen
        if (fz is None or fz["base"] is not base or fz["t0"] != t0
                or fz["dyn_pre"] != dyn_pre
                or (touched != fz["touched"]
                    and (touched ^ fz["touched"]) & fz["rel_pre"])):
            fz = self._freeze(base, dyn, touched, dyn_pre, t0)

        # suffix = (touched-filtered base rows >= t0) + dyn rows >= t0,
        # merged with the same tie rules as the full sweep
        bt, bo, bd, bk, brel = fz["bsuf"]
        n_keys = len(base.key_names)
        if touched:
            tbl = np.zeros(n_keys, dtype=bool)
            tbl[[base.key_index[st] for st in touched
                 if st in base.key_index]] = True
            keep = ~(brel & tbl[bk])
            bt, bo, bd, bk = bt[keep], bo[keep], bd[keep], bk[keep]
        if dyn_suf:
            dt, do, dd, dk = _events_to_arrays(dyn_suf, base)
            pos = _insert_positions(bt, bo, dt, do)
            ts, ds = np.insert(bt, pos, dt), np.insert(bd, pos, dd)
            ks = np.insert(bk, pos, dk)
        else:
            ts, ds, ks = bt, bd, bk

        eff = _effective_mask(ks, ds, init_sign=fz["resident"],
                              n_keys=n_keys)
        sign = ds > 0
        mem = fz["mem0"] + np.cumsum(np.where(eff, ds, 0))

        def timeline_fn(fz=fz, ts=ts, eff=eff, mem=mem):
            return fz["timeline"] + list(zip(ts[eff].tolist(),
                                             mem[eff].tolist()))

        cand = eff & (ts >= t0) & (ts < hi)
        peak, peak_time, peak_loc = 0, 0.0, -1
        ci = np.flatnonzero(cand)
        if len(ci):
            cm = mem[ci]
            pmax = int(cm.max())
            if pmax > 0:
                j = int(ci[int(np.argmax(cm))])
                peak, peak_time, peak_loc = pmax, float(ts[j]), j

        def peak_tensors_fn(fz=fz, ks=ks, sign=sign, eff=eff,
                            peak_loc=peak_loc, base=base, seq=seq):
            if peak_loc < 0:
                return []
            P = peak_loc + 1
            ls = fz["resident"].copy()
            ls[ks[:P]] = sign[:P]
            ap = fz["ap"].copy()
            ii = np.flatnonzero(eff[:P] & sign[:P])
            ap[ks[ii]] = fz["cut"] + ii
            res = np.flatnonzero(ls)
            res = res[np.argsort(ap[res], kind="stable")]
            res = res[np.argsort(-base.key_sizes[res], kind="stable")]
            return [(base.key_names[i], seq.job_id,
                     int(base.key_sizes[i])) for i in res.tolist()]

        am = eff & sign
        pj: Dict[str, int] = {}
        vals = [v for v in (fz["pj_max"],
                            int(mem[am].max()) if am.any() else None)
                if v is not None]
        if vals:
            pj[seq.job_id] = max(vals)

        lua = {seq.job_id: 0.0}
        i = int(np.searchsorted(base.tua_arr, peak_time, side="right"))
        if i:
            lua[seq.job_id] = float(base.tua_arr[i - 1])
        return PeakReport(peak_bytes=peak, peak_time=peak_time,
                          peak_tensors_fn=peak_tensors_fn,
                          last_input_access=lua, timeline_fn=timeline_fn,
                          per_job_peak=pj)


def _reference_sweep(seqs: Sequence[AccessSequence],
                     plans: Optional[Dict[str, SchedulingPlan]] = None,
                     offsets: Optional[Dict[str, float]] = None,
                     window: Optional[Tuple[float, float]] = None,
                     free_at_last_use: bool = True) -> PeakReport:
    """The original per-event Algorithm-2 sweep, kept verbatim as the
    semantic reference: the equivalence tests assert ``analyze`` (the
    vectorized sweep) reproduces every PeakReport field byte-identically.
    Not on any hot path."""
    plans = plans or {}
    offsets = offsets or {}
    streams = []
    tuas: List[Tuple[float, str]] = []
    for seq in seqs:
        off = offsets.get(seq.job_id, 0.0)
        base = _job_base(seq, free_at_last_use)
        plan = plans.get(seq.job_id)
        if plan is not None and (plan.events or plan.release_after_op):
            dyn, touched = _plan_events(seq, plan, base)
        else:
            dyn, touched = [], set()
        streams.append(_offset_iter(base.fixed, off))
        if touched:
            streams.append(_offset_iter(
                (e for e in base.releases if e.storage not in touched), off))
        else:
            streams.append(_offset_iter(base.releases, off))
        if dyn:
            streams.append(_offset_iter(dyn, off))
        tuas.extend((t + off, seq.job_id) for t in base.tua_times)
    events = list(heapq.merge(*streams, key=_ekey))
    tuas.sort()

    # --- pass 1: find the peak index (no snapshots: snapshotting/sorting
    # the resident set at every running peak was O(n²) and dominated the
    # scheduler's runtime on DenseNet-scale graphs) -----------------------
    resident: Dict[Tuple[str, str], int] = {}
    mem = 0
    peak, peak_time, peak_idx = 0, 0.0, -1
    timeline: List[Tuple[float, int]] = []
    per_job: Dict[str, int] = {}
    job_mem: Dict[str, int] = {}

    for i, ev in enumerate(events):
        key = (ev.job_id, ev.storage)
        if ev.delta > 0:
            if key in resident:
                continue  # already resident (idempotent alloc)
            resident[key] = ev.delta
            mem += ev.delta
            jm = job_mem.get(ev.job_id, 0) + ev.delta
            job_mem[ev.job_id] = jm
            if jm > per_job.get(ev.job_id, 0):
                per_job[ev.job_id] = jm
        else:
            if key not in resident:
                continue  # already freed (idempotent free)
            sz = resident.pop(key)
            mem -= sz
            job_mem[ev.job_id] = job_mem.get(ev.job_id, 0) - sz
        timeline.append((ev.time, mem))
        in_window = window is None or (window[0] <= ev.time < window[1])
        if in_window and mem > peak:
            peak, peak_time, peak_idx = mem, ev.time, i

    # --- pass 2: replay to the peak index, reconstruct MPT + LUA once ----
    resident.clear()
    for ev in events[:peak_idx + 1]:
        key = (ev.job_id, ev.storage)
        if ev.delta > 0:
            resident.setdefault(key, ev.delta)
        else:
            resident.pop(key, None)
    peak_resident = sorted(
        ((st, j, sz) for (j, st), sz in resident.items()),
        key=lambda x: -x[2])
    lua: Dict[str, float] = {s.job_id: 0.0 for s in seqs}
    for t, j in tuas:
        if t > peak_time:
            break
        lua[j] = t
    return PeakReport(peak_bytes=peak, peak_time=peak_time,
                      peak_tensors=peak_resident, last_input_access=lua,
                      timeline=timeline, per_job_peak=per_job)


def vanilla_peak(seq: AccessSequence, free_at_last_use: bool = False) -> int:
    """Peak with no scheduling at all — the paper's vanilla group (VMP):
    on the paper's platform nothing is freed until the iteration ends."""
    return analyze([seq], free_at_last_use=free_at_last_use).peak_bytes


def unroll(seq: AccessSequence, n_iters: int = 2) -> AccessSequence:
    """Unroll `n_iters` iterations of a job into one sequence.

    Persistent tensors (params, optimizer state, and updated-parameter
    aliases) keep their identity across iterations; activations, gradients
    and inputs become per-iteration instances (``tid~k``).
    """
    from .access import Operator  # local import to avoid cycles

    def persists(spec: TensorSpec) -> bool:
        return spec.kind in PERSISTENT_KINDS or spec.updates is not None

    ops: List[Operator] = []
    tensors: Dict[str, TensorSpec] = {}
    n_ops = len(seq.operators)

    def rename(tid: str, k: int) -> str:
        spec = seq.tensors.get(tid)
        if spec is None or persists(spec):
            return tid
        return f"{tid}~{k}"

    for k in range(n_iters):
        for op in seq.operators:
            ops.append(Operator(
                idx=k * n_ops + op.idx, name=op.name,
                inputs=tuple(rename(t, k) for t in op.inputs),
                outputs=tuple(rename(t, k) for t in op.outputs),
                latency=op.latency, flops=op.flops,
                bytes_accessed=op.bytes_accessed, phase=op.phase,
                params=op.params, job_id=op.job_id))
        for tid, spec in seq.tensors.items():
            new_id = rename(tid, k)
            if new_id in tensors:
                continue
            tensors[new_id] = dataclasses.replace(spec, tid=new_id)
    initial = [rename(t, 0) for t in seq.initial_resident]
    out = AccessSequence(seq.job_id, ops, tensors, initial_resident=initial)
    return out
