"""Interpreting Executor (paper §III-D: Executor + Swap Executor).

Runs a captured jaxpr equation-by-equation against the shared MemoryEngine:
the engine's DeviceLedger does the byte-exact residency accounting, its
DmaChannel serializes transfers, and its JobContext supplies every residency
*decision* (when a planned event applies, when an operand needs a passive
swap-in or a recompute, when a tensor auto-releases) — the same rules the
discrete-event simulator runs, so simulated and real executions of a plan
agree by construction (tests/test_engine_parity.py).

On this container "device" and "host" are both CPU RAM, so residency is
tracked logically (exact aval bytes) while the *data path* is real: swapped
tensors are copied into the host store, dropped from the device store, and
swapped back (or recomputed from their producer equation) before use;
compressed events round-trip through the Pallas quantize-on-offload kernels.
Final outputs are verified against an un-scheduled reference execution.

Both stores are keyed by **storage id**: an updated parameter aliases the old
parameter's storage (paper §IV-B situation 2), so the Opt-phase update
overwrites in place instead of double-counting.

Two swap modes:
  * sync  — swap events execute inline at their trigger (deterministic;
            tests and the parity check against simulate(transfer_mode="sync")).
  * async — a Swap Executor thread drains an event queue while compute
            proceeds, serialized by the engine channel (paper Fig. 4); used
            by the multi-workload runtime for real overlap and contention.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time as _time
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import numpy as np
from jax.extend import core as jcore

from .access import AccessSequence
from .engine import (INPUT_AWAIT_PREFETCH, INPUT_PASSIVE_SWAP_IN,
                     INPUT_RESIDENT, DeviceLedger, DmaChannel, MemoryEngine,
                     ResidencyView)
from .plan import EventType, SchedulingPlan
from .telemetry import TelemetryHub

# Back-compat names: the seed defined these locally; they now live in (and
# are shared through) the engine.
DeviceAccountant = DeviceLedger
SwapChannel = DmaChannel


@dataclasses.dataclass
class ExecutionStats:
    peak_bytes: int = 0
    wall_time_s: float = 0.0
    swap_out_count: int = 0
    swap_in_count: int = 0
    passive_swap_ins: int = 0
    recompute_count: int = 0
    compressed_swaps: int = 0
    op_latencies: Optional[List[float]] = None
    stall_time_s: float = 0.0
    # mid-iteration plan hot-swaps applied at a safe point
    hot_swaps: int = 0
    # queued (unstarted) prefetches cancelled when a hot-swap revised
    # swap-INs already booked on the channel
    canceled_swap_ins: int = 0
    # measured per-job residency timeline of THIS iteration, (t, bytes)
    # in hub time — filled from the TelemetryHub when one is attached
    residency_timeline: Optional[List[tuple]] = None


class AsyncSwapExecutor:
    """Paper Fig. 4: an execution-queue thread pops swap events and runs them
    on the shared engine channel.

    The worker is a double-buffered stream: after popping one transfer it
    non-blockingly drains any *same-direction* transfers already queued
    behind it and runs the whole cohort as ONE ``channel.transfer_batch``
    launch — queued prefetches coalesce on the wire instead of paying one
    channel round-trip each.  ``batches`` traces each coalesced launch
    (the regression test asserts two queued prefetches share one)."""

    MAX_BATCH = 8

    def __init__(self, channel: DmaChannel):
        self.channel = channel
        self.q: "queue.Queue" = queue.Queue()
        self.inflight: Dict[str, threading.Event] = {}
        self._stop = False
        # state_lock guards running/poisoned: `running` holds the keys
        # whose transfers are physically on the wire (a coalesced batch
        # carries several); `poisoned` keys were cancelled after the
        # worker popped them but before it started — the worker discards
        # them instead of transferring
        self.state_lock = threading.Lock()
        self.running: set = set()
        self.poisoned: set = set()
        # keys of each coalesced launch, in completion order
        self.batches: List[List[str]] = []
        # one popped-but-deferred item of the OTHER direction (keeps FIFO
        # order across direction changes without a peekable queue)
        self._carry = None
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def submit(self, key: str, fn) -> threading.Event:
        done = threading.Event()
        self.inflight[key] = done
        self.q.put((key, fn, done))
        return done

    @staticmethod
    def _direction(key: str) -> str:
        return key.split(":", 1)[0]

    def _run(self):
        while not self._stop:
            if self._carry is not None:
                item, self._carry = self._carry, None
            else:
                try:
                    item = self.q.get(timeout=0.05)
                except queue.Empty:
                    continue
            batch = [item]
            prefix = self._direction(item[0])
            while len(batch) < self.MAX_BATCH:
                try:
                    nxt = self.q.get_nowait()
                except queue.Empty:
                    break
                if self._direction(nxt[0]) == prefix:
                    batch.append(nxt)
                else:
                    self._carry = nxt
                    break
            live = []
            with self.state_lock:
                for key, fn, done in batch:
                    if key in self.poisoned:
                        self.poisoned.discard(key)
                        done.set()
                        self.inflight.pop(key, None)
                    else:
                        live.append((key, fn, done))
                        self.running.add(key)
            if not live:
                continue
            try:
                if len(live) == 1:
                    self.channel.transfer(live[0][1])
                else:
                    self.channel.transfer_batch([fn for _, fn, _ in live])
            finally:
                with self.state_lock:
                    for key, _, _ in live:
                        self.running.discard(key)
                self.batches.append([key for key, _, _ in live])
                for key, _, done in live:
                    done.set()
                    self.inflight.pop(key, None)

    def cancel_unstarted(self, prefix: str = "") -> Optional[List[str]]:
        """Cancel every transfer whose key starts with ``prefix`` that
        has NOT physically started — queued items are drained, items the
        worker already popped (but not started) are poisoned so it
        discards them.  Returns None WITHOUT cancelling anything when a
        matching transfer is on the wire (the caller must defer), else
        the cancelled keys.  Waiters are released — ``_ensure_input``
        re-derives the action, so a consumer of a cancelled prefetch
        falls back to a passive swap-in."""
        with self.state_lock:
            if any(k.startswith(prefix) for k in self.running):
                return None
            cancelled: List[str] = []
            requeue = []
            while True:
                try:
                    item = self.q.get_nowait()
                except queue.Empty:
                    break
                key, _fn, done = item
                if key.startswith(prefix):
                    cancelled.append(key)
                    self.inflight.pop(key, None)
                    done.set()
                else:
                    requeue.append(item)
            for item in requeue:
                self.q.put(item)
            # popped-but-unstarted items (incl. a carried one) are blocked
            # on state_lock right now: poison them, the worker will
            # discard and release them
            for key in list(self.inflight):
                if key.startswith(prefix) and key not in self.running:
                    self.poisoned.add(key)
                    cancelled.append(key)
            return cancelled

    def drain(self):
        # every submitted-but-unfinished key sits in `inflight` until its
        # completion event fires — wait on the events themselves instead
        # of busy-polling the queue
        while self.inflight:
            for ev in list(self.inflight.values()):
                ev.wait()

    def stop(self):
        self.drain()
        self._stop = True


def _is_dropvar(v) -> bool:
    return type(v).__name__ == "DropVar"


class JaxprExecutor:
    def __init__(self, closed_jaxpr, seq: AccessSequence,
                 plan: Optional[SchedulingPlan] = None,
                 accountant: Optional[DeviceLedger] = None,
                 channel: Optional[DmaChannel] = None,
                 async_swap: bool = False,
                 measure_latency: bool = False,
                 host_resident_inputs: Optional[Set[str]] = None,
                 engine: Optional[MemoryEngine] = None,
                 telemetry: Optional[TelemetryHub] = None):
        self.closed = closed_jaxpr
        self.jaxpr = closed_jaxpr.jaxpr
        self.seq = seq
        self.plan = plan
        self.engine = engine or MemoryEngine(ledger=accountant,
                                             channel=channel)
        if telemetry is not None:
            self.engine.attach_telemetry(telemetry)
        self.telemetry = self.engine.telemetry
        self.ctx = self.engine.add_job(seq, plan)
        self.accountant = self.engine.ledger
        self.channel = self.engine.channel
        self.async_exec = AsyncSwapExecutor(self.channel) if async_swap else None
        self.measure_latency = measure_latency
        # storages whose *input* value starts on host (previous iteration's
        # cross-iteration swap-out; paper Fig. 1(c) steady state)
        self.host_resident_inputs: Set[str] = set(host_resident_inputs or ())

        self.device: Dict[str, Any] = {}
        self.host: Dict[str, Any] = {}
        # double-buffered swap-outs: storage -> (completion event,
        # compressed).  The device copy is retired (trace record, ledger
        # free, stats) only when the copy has landed — observed at the
        # next completion-poll point instead of a blocking wait.
        self._pending_out: Dict[str, Tuple[threading.Event, bool]] = {}
        # decisions consult THIS iteration's value store, not the (possibly
        # longer-lived, controller-shared) ledger
        self.resident = ResidencyView(self.device)

        self.var_by_name: Dict[str, Any] = {}
        self._name: Dict[Any, str] = {}
        # naming order must match graph_capture.capture exactly
        for v in list(self.jaxpr.invars) + list(self.jaxpr.constvars):
            self._name_of(v)
        for eqn in self.jaxpr.eqns:
            for v in eqn.outvars:
                self._name_of(v)

        self.producer: Dict[str, int] = {}
        for i, eqn in enumerate(self.jaxpr.eqns):
            for v in eqn.outvars:
                self.producer[self._name_of(v)] = i
        self.stats = ExecutionStats(op_latencies=[] if measure_latency else None)
        self._cur_idx = -1
        # pending mid-iteration plan hot-swap: (plan, eligible safe ops),
        # set by the controller thread, consumed at a safe point in run()
        self._plan_lock = threading.Lock()
        self._pending_plan: Optional[Tuple[SchedulingPlan, frozenset]] = None

    # ------------------------------------------------------------------
    @property
    def current_op_index(self) -> int:
        """Index of the equation being executed (-1 before the first) —
        the controller reads this to pick a safe point still ahead of the
        run when requesting a preemptive plan hot-swap."""
        return self._cur_idx

    def request_plan(self, plan: SchedulingPlan,
                     safe_ops) -> None:
        """Thread-safe mid-iteration plan hot-swap request (preemptive
        arbitration).  The new plan is spliced in at the next safe point
        the run reaches: an op boundary in ``safe_ops`` with no transfer
        of this job in flight.  A later request supersedes an unapplied
        earlier one.  If no listed safe point remains this iteration, the
        request simply never fires — the boundary plan pickup covers it."""
        with self._plan_lock:
            self._pending_plan = (plan, frozenset(safe_ops))

    def _maybe_hot_swap(self, idx: int) -> None:
        """Splice the pending plan in if op boundary `idx` is an eligible
        safe point.  Runs on the executor thread right after the op's plan
        events, mirroring the simulator's splice instant exactly.

        Swap-INs already booked on the channel do not block the splice:
        queued prefetches the Swap Executor has not started yet are
        CANCELLED (the new plan re-books what it still needs; a consumer
        of a cancelled prefetch degrades to a passive swap-in) — only a
        transfer physically in progress defers the splice to the next
        safe point."""
        if self._pending_plan is None:
            return
        with self._plan_lock:
            if self._pending_plan is None:
                return
            plan, safe_ops = self._pending_plan
            if idx not in safe_ops:
                return
            # a splice needs quiescence: wait out our own in-flight
            # swap-outs (short copies; the pre-double-buffer executor
            # blocked on them at issue time, so this preserves the PR-4
            # cancel/defer semantics exactly)
            self._poll_swap_outs(block=True)
            if self.async_exec and self.async_exec.inflight:
                cancelled = self.async_exec.cancel_unstarted("in:")
                if cancelled is None:
                    # a prefetch is physically on the wire: defer to the
                    # next safe point.  cancel_unstarted cancels NOTHING
                    # in that case, so the still-running old plan keeps
                    # every prefetch it queued.
                    return
                with self.async_exec.state_lock:
                    blocking = [k for k in self.async_exec.inflight
                                if k not in self.async_exec.poisoned]
                if blocking:
                    return       # e.g. a swap-out raced in: next point
                self.stats.canceled_swap_ins += len(cancelled)
            self.plan = plan
            self.ctx.set_plan(plan)
            self.stats.hot_swaps += 1
            self._pending_plan = None
            rec = self.engine.recorder
            if rec is not None:
                t = self.telemetry.now() if self.telemetry is not None \
                    else 0.0
                rec.instant("hot_swap", t, job_id=self.ctx.job_id,
                            site="safe-point", op_idx=idx)

    # ------------------------------------------------------------------
    def _name_of(self, v) -> str:
        if v not in self._name:
            nm = f"v{len(self._name)}"
            self._name[v] = nm
            self.var_by_name[nm] = v
        return self._name[v]

    def _st(self, name: str) -> str:
        return self.ctx.st(name)

    def _put_device(self, name: str, val: Any) -> None:
        st = self._st(name)
        if st in self.device:
            self.device[st] = val  # in-place overwrite (aliased update)
            return
        self.device[st] = val
        self.accountant.alloc(self.ctx.job_id, st,
                              self.ctx.sizes.get(st, _arr_bytes(val)))

    def _drop_device(self, name: str) -> None:
        self._drop_storage(self._st(name))

    def _drop_storage(self, st: str) -> None:
        if st in self.device:
            self.device.pop(st)
            self.accountant.free(self.ctx.job_id, st)

    def _get(self, name: str):
        return self.device.get(self._st(name))

    # ------------------------------------------------------------------
    def _host_put(self, st: str, val: Any, compressed: bool) -> None:
        self.host[st] = val
        self.ctx.host.add(st)
        if compressed:
            self.ctx.host_compressed.add(st)
        else:
            self.ctx.host_compressed.discard(st)

    def _host_fetch(self, st: str):
        """Materialize a device value from the host store (dequantizing a
        compressed copy through the Pallas kernel)."""
        val = self.host[st]
        if st in self.ctx.host_compressed:
            from repro.kernels.offload_quant import dequantize_blocked
            q, s, meta = val
            return dequantize_blocked(q, s, meta)
        return jax.numpy.asarray(val)

    def _swap_out(self, name: str, compressed: bool = False) -> None:
        st = self._st(name)
        if st not in self.device or st in self._pending_out:
            return
        val = self.device[st]

        def do():
            hub = self.telemetry
            ts = hub.now() if hub is not None else 0.0
            t0 = _time.perf_counter()
            if compressed:
                from repro.kernels.offload_quant import quantize_blocked
                self._host_put(st, quantize_blocked(jax.numpy.asarray(val)),
                               compressed=True)
            else:
                self._host_put(st, np.asarray(val), compressed=False)
            if hub is not None:
                hub.record_transfer(
                    self.ctx.job_id, st, "out", self.ctx.size_of(st),
                    _time.perf_counter() - t0, compressed=compressed, t=ts)

        if self.async_exec:
            # double-buffered stream: compute proceeds while the copy is
            # on the wire; the device copy is retired at the next poll
            # point, never before the copy lands (paper semantics kept —
            # the ledger free happens only after completion)
            done = self.async_exec.submit("out:" + st, do)
            self._pending_out[st] = (done, compressed)
            return
        self.channel.transfer(do)
        self._retire_out(st, compressed)

    def _retire_out(self, st: str, compressed: bool) -> None:
        """A swap-out's copy has landed: record, free the device copy,
        count."""
        self.engine.record("swap_out", self.ctx, st)
        self._drop_storage(st)
        self.stats.swap_out_count += 1
        if compressed:
            self.stats.compressed_swaps += 1

    def _poll_swap_outs(self, block: bool = False) -> None:
        """Non-blocking completion poll of in-flight swap-outs (the other
        half of the double buffer): retire every copy that has landed.
        With ``block=True`` wait for all of them (drain / safe points)."""
        for st, (done, compressed) in list(self._pending_out.items()):
            if block:
                done.wait()
            if done.is_set():
                del self._pending_out[st]
                self._retire_out(st, compressed)

    def _swap_in(self, name: str, passive: bool) -> bool:
        """Prefetch from host; returns False when there is nothing to fetch
        (e.g. iteration-0 cold start of a cross-iteration plan)."""
        st = self._st(name)
        if st in self.device:
            return True
        if st not in self.host:
            return False
        compressed = st in self.ctx.host_compressed

        def do():
            hub = self.telemetry
            ts = hub.now() if hub is not None else 0.0
            t0 = _time.perf_counter()
            self._put_device(st, self._host_fetch(st))
            if hub is not None:
                hub.record_transfer(
                    self.ctx.job_id, st, "in", self.ctx.size_of(st),
                    _time.perf_counter() - t0, compressed=compressed,
                    passive=passive, t=ts)

        self.engine.record("passive_in" if passive else "swap_in",
                           self.ctx, st)
        if self.async_exec and not passive:
            self.async_exec.submit("in:" + st, do)
        else:
            t0 = _time.perf_counter()
            self.channel.transfer(do)
            if passive:
                self.stats.passive_swap_ins += 1
                stall = _time.perf_counter() - t0
                self.stats.stall_time_s += stall
                if self.telemetry is not None:
                    self.telemetry.record_stall(
                        self.ctx.job_id, self._cur_idx, stall, "passive_in")
        self.stats.swap_in_count += 1
        return True

    def _ensure_input(self, name: str) -> None:
        """An operator needs `name` now: prefetch-wait, passive swap-in, or
        recompute from the producer equation (engine decision rules)."""
        st = self._st(name)
        inflight = bool(self.async_exec
                        and ("in:" + st) in self.async_exec.inflight)
        action = self.ctx.input_action(self.resident, name,
                                       prefetch_inflight=inflight)
        if action is INPUT_RESIDENT:
            return
        if action is INPUT_AWAIT_PREFETCH:
            ts = _time.perf_counter()
            self.async_exec.inflight["in:" + st].wait()
            stall = _time.perf_counter() - ts
            self.stats.stall_time_s += stall
            if self.telemetry is not None:
                self.telemetry.record_stall(
                    self.ctx.job_id, self._cur_idx, stall, "await_prefetch")
            if st in self.device:
                return
            action = self.ctx.input_action(self.resident, name)
        if action is INPUT_PASSIVE_SWAP_IN and self._swap_in(st, passive=True):
            return
        self._recompute(name)

    def _recompute(self, name: str) -> None:
        eqn_idx = self.producer.get(name)
        if eqn_idx is None:
            raise KeyError(f"tensor {name} unavailable and has no producer")
        eqn = self.jaxpr.eqns[eqn_idx]
        invals = []
        for v in eqn.invars:
            if isinstance(v, jcore.Literal):
                invals.append(v.val)
                continue
            nm = self._name_of(v)
            self._ensure_input(nm)
            invals.append(self._get(nm))
        outs = _eval_eqn(eqn, invals)
        for v, o in zip(eqn.outvars, outs):
            if not _is_dropvar(v):
                self._put_device(self._name_of(v), o)
        self.stats.recompute_count += 1

    # ------------------------------------------------------------------
    def run(self, *args: Any) -> Any:
        t_start = _time.perf_counter()
        res_start = 0
        if self.telemetry is not None:
            res_start = len(
                self.telemetry.residency.get(self.ctx.job_id, ()))
        # absorb host values preloaded by the controller between iterations
        self.ctx.host |= set(self.host)
        flat, _ = jax.tree.flatten(args)
        assert len(flat) == len(self.jaxpr.invars), \
            f"expected {len(self.jaxpr.invars)} leaves, got {len(flat)}"
        for v, val in zip(self.jaxpr.invars, flat):
            nm = self._name_of(v)
            st = self._st(nm)
            if st in self.host_resident_inputs:
                # previous iteration parked this storage on host; it enters
                # the device only via its planned swap-in (or passively)
                self._host_put(st, np.asarray(val), compressed=False)
            else:
                self._put_device(nm, val)
        for v, val in zip(self.jaxpr.constvars, self.closed.consts):
            self._put_device(self._name_of(v), val)

        measure = self.measure_latency or self.telemetry is not None
        if self.telemetry is not None:
            # hot path: telemetry appends go through a per-thread buffer
            # flushed once per op boundary (one lock round-trip per op
            # instead of one per record)
            self.telemetry.begin_buffering()
        for idx, eqn in enumerate(self.jaxpr.eqns):
            self._cur_idx = idx
            # retire any swap-out whose copy landed while we computed
            self._poll_swap_outs()
            t0 = _time.perf_counter()
            invals = []
            for v in eqn.invars:
                if isinstance(v, jcore.Literal):
                    invals.append(v.val)
                    continue
                nm = self._name_of(v)
                self._ensure_input(nm)
                invals.append(self._get(nm))
            t1 = _time.perf_counter()
            outs = _eval_eqn(eqn, invals)
            if measure:
                jax.block_until_ready(outs)
                t2 = _time.perf_counter()
                if self.measure_latency:
                    self.stats.op_latencies.append(t2 - t0)
                if self.telemetry is not None:
                    # compute-only latency: input-ensure time is reported
                    # separately as stall records, so calibration samples
                    # are not polluted by memory waits
                    op = (self.seq.operators[idx]
                          if idx < len(self.seq.operators) else None)
                    self.telemetry.record_op(
                        self.ctx.job_id, idx, t2 - t1,
                        prim=eqn.primitive.name,
                        flops=op.flops if op else 0.0,
                        bytes_accessed=op.bytes_accessed if op else 0.0)
            for v, o in zip(eqn.outvars, outs):
                # dropped results still occupy their buffer until the op's
                # releases run — the allocator model both runtimes share
                self._put_device(self._name_of(v), o)

            # releases: plan overrides, then free-at-last-use (engine rule)
            for v in list(eqn.invars) + list(eqn.outvars):
                if isinstance(v, jcore.Literal):
                    continue
                nm = self._name_of(v)
                if self.ctx.should_auto_release(nm, idx):
                    self.engine.record("release", self.ctx, self._st(nm))
                    self._drop_device(nm)

            # plan events triggered by this op (engine skip rules)
            for ev in self.ctx.events_triggered_by(idx):
                st = self._st(ev.tensor_id)
                if not self.ctx.event_applies(self.resident, ev):
                    continue
                if ev.event_type is EventType.SWAP_OUT:
                    self._swap_out(ev.tensor_id, compressed=ev.compressed)
                elif ev.event_type is EventType.SWAP_IN:
                    self._swap_in(ev.tensor_id, passive=False)
                elif ev.event_type is EventType.RELEASE:
                    self.engine.record("release", self.ctx, st)
                    self._drop_device(ev.tensor_id)
                elif ev.event_type is EventType.RECOMPUTE:
                    self.engine.record("recompute", self.ctx, st)
                    self._recompute(ev.tensor_id)

            # preemptive arbitration: splice a pending plan in at a safe
            # point (after this op's events, before the next op)
            self._maybe_hot_swap(idx)
            if self.telemetry is not None:
                self.telemetry.flush()

        if self.async_exec:
            self.async_exec.drain()
        self._poll_swap_outs(block=True)
        if self.telemetry is not None:
            self.telemetry.end_buffering()
        # fetching outputs back to Python is harness work, not part of the
        # modeled iteration (steady state leaves swapped outputs on host) —
        # pause the trace (and telemetry) for it, resume afterwards
        if self.engine.trace is not None:
            self.engine.trace.paused = True
        if self.telemetry is not None:
            self.telemetry.paused = True
        outs = []
        for v in self.jaxpr.outvars:
            if isinstance(v, jcore.Literal):
                outs.append(v.val)
                continue
            nm = self._name_of(v)
            if self._get(nm) is None:
                self._ensure_input(nm)
            outs.append(self._get(nm))
        if self.engine.trace is not None:
            self.engine.trace.paused = False
        if self.telemetry is not None:
            self.telemetry.paused = False
            self.stats.residency_timeline = [
                (r.t, r.resident_bytes)
                for r in self.telemetry.residency.get(
                    self.ctx.job_id, [])[res_start:]]
            self.telemetry.end_iteration(self.ctx.job_id)
        self.stats.wall_time_s = _time.perf_counter() - t_start
        self.stats.peak_bytes = self.accountant.peak
        return outs

    # ------------------------------------------------------------------
    def ending_host_storages(self) -> Set[str]:
        """Storages left parked on host at iteration end (their device copy
        dropped) — the next iteration's `host_resident_inputs`."""
        return {st for st in self.host if st not in self.device}

    def close(self):
        if self.async_exec:
            self.async_exec.stop()


def _arr_bytes(x) -> int:
    try:
        return int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    except Exception:
        return 0


def _eval_eqn(eqn, invals: List[Any]) -> List[Any]:
    """Evaluate one jaxpr equation.  Call-like primitives run their
    sub-jaxpr through jaxpr_as_fun; everything else binds directly."""
    prim = eqn.primitive
    name = prim.name
    if name == "pjit":
        sub = eqn.params["jaxpr"]
        outs = jcore.jaxpr_as_fun(sub)(*invals)
        return list(outs)
    if name in ("custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
                "remat", "checkpoint"):
        sub = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr") \
            or eqn.params.get("jaxpr")
        if sub is not None:
            closed = sub if hasattr(sub, "consts") else jcore.ClosedJaxpr(sub, [])
            return list(jcore.jaxpr_as_fun(closed)(*invals))
    outs = prim.bind(*invals, **eqn.params)
    if not prim.multiple_results:
        outs = [outs]
    return list(outs)


def reference_outputs(closed_jaxpr, *args: Any) -> List[Any]:
    flat, _ = jax.tree.flatten(args)
    return list(jcore.jaxpr_as_fun(closed_jaxpr)(*flat))
