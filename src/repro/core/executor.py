"""Interpreting Executor (paper §III-D: Executor + Swap Executor).

Runs a captured jaxpr equation-by-equation with an explicit device-residency
accountant, a host store, and plan-driven swap / release / recompute events —
the same architecture as the paper's framework (which interprets a tinyflow
graph op-by-op).  On this container "device" and "host" are both CPU RAM, so
residency is tracked logically (exact aval bytes) while the *data path* is
real: swapped tensors are copied into the host store, dropped from the device
store, and swapped back (or recomputed from their producer equation) before
use; final outputs are verified against an un-scheduled reference execution.

Both stores are keyed by **storage id**: an updated parameter aliases the old
parameter's storage (paper §IV-B situation 2), so the Opt-phase update
overwrites in place instead of double-counting.

Two swap modes:
  * sync  — swap events execute inline at their trigger (deterministic; tests).
  * async — a Swap Executor thread drains an event queue while compute
            proceeds, serialized by a channel lock (paper Fig. 4); used by
            the multi-workload runtime for real overlap and contention.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time as _time
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import numpy as np
from jax.extend import core as jcore

from .access import AccessSequence, TensorKind
from .peak_analysis import PERSISTENT_KINDS, storage_of
from .plan import EventType, ScheduleEvent, SchedulingPlan


class DeviceAccountant:
    """Logical device-memory accounting shared by all jobs on the device."""

    def __init__(self, capacity_bytes: Optional[int] = None):
        self.capacity = capacity_bytes
        self.used = 0
        self.peak = 0
        self.lock = threading.Lock()
        self.timeline: List[Tuple[float, int]] = []
        self.oom_events = 0

    def alloc(self, n: int) -> None:
        with self.lock:
            self.used += n
            if self.capacity is not None and self.used > self.capacity:
                self.oom_events += 1
            self.peak = max(self.peak, self.used)
            self.timeline.append((_time.perf_counter(), self.used))

    def free(self, n: int) -> None:
        with self.lock:
            self.used -= n
            self.timeline.append((_time.perf_counter(), self.used))


@dataclasses.dataclass
class ExecutionStats:
    peak_bytes: int = 0
    wall_time_s: float = 0.0
    swap_out_count: int = 0
    swap_in_count: int = 0
    passive_swap_ins: int = 0
    recompute_count: int = 0
    op_latencies: Optional[List[float]] = None
    stall_time_s: float = 0.0


class SwapChannel:
    """One transfer at a time, across every job on the host (paper §IV-A)."""

    def __init__(self):
        self.lock = threading.Lock()
        self.busy_s = 0.0

    def transfer(self, fn):
        with self.lock:
            t0 = _time.perf_counter()
            out = fn()
            self.busy_s += _time.perf_counter() - t0
            return out


class AsyncSwapExecutor:
    """Paper Fig. 4: an execution-queue thread pops swap events and runs them
    on the shared channel."""

    def __init__(self, channel: SwapChannel):
        self.channel = channel
        self.q: "queue.Queue" = queue.Queue()
        self.inflight: Dict[str, threading.Event] = {}
        self._stop = False
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def submit(self, key: str, fn) -> threading.Event:
        done = threading.Event()
        self.inflight[key] = done
        self.q.put((key, fn, done))
        return done

    def _run(self):
        while not self._stop:
            try:
                key, fn, done = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            try:
                self.channel.transfer(fn)
            finally:
                done.set()
                self.inflight.pop(key, None)

    def drain(self):
        while not self.q.empty():
            _time.sleep(0.001)
        for ev in list(self.inflight.values()):
            ev.wait()

    def stop(self):
        self.drain()
        self._stop = True


def _is_dropvar(v) -> bool:
    return type(v).__name__ == "DropVar"


class JaxprExecutor:
    def __init__(self, closed_jaxpr, seq: AccessSequence,
                 plan: Optional[SchedulingPlan] = None,
                 accountant: Optional[DeviceAccountant] = None,
                 channel: Optional[SwapChannel] = None,
                 async_swap: bool = False,
                 measure_latency: bool = False,
                 host_resident_inputs: Optional[Set[str]] = None):
        self.closed = closed_jaxpr
        self.jaxpr = closed_jaxpr.jaxpr
        self.seq = seq
        self.plan = plan
        self.accountant = accountant or DeviceAccountant()
        self.channel = channel or SwapChannel()
        self.async_exec = AsyncSwapExecutor(self.channel) if async_swap else None
        self.measure_latency = measure_latency
        # storages whose *input* value starts on host (previous iteration's
        # cross-iteration swap-out; paper Fig. 1(c) steady state)
        self.host_resident_inputs: Set[str] = set(host_resident_inputs or ())

        self.device: Dict[str, Any] = {}
        self.host: Dict[str, np.ndarray] = {}
        # stores keyed by storage id: updated params alias the old param's
        # storage (paper §IV-B), the Opt update overwrites in place
        self.storage: Dict[str, str] = {}
        self.sizes: Dict[str, int] = {}
        for t in seq.tensors.values():
            st = storage_of(t)
            self.storage[t.tid] = st
            self.sizes[st] = max(self.sizes.get(st, 0), t.size_bytes)

        self.var_by_name: Dict[str, Any] = {}
        self._name: Dict[Any, str] = {}
        # naming order must match graph_capture.capture exactly
        for v in list(self.jaxpr.invars) + list(self.jaxpr.constvars):
            self._name_of(v)
        for eqn in self.jaxpr.eqns:
            for v in eqn.outvars:
                self._name_of(v)

        # last use per *storage* (any alias)
        self.last_use: Dict[str, int] = {}
        for tid, idx in seq.activity_analysis().items():
            st = self.storage.get(tid, tid)
            self.last_use[st] = max(self.last_use.get(st, -1), idx)

        self.by_trigger: Dict[int, List[ScheduleEvent]] = {}
        self.recompute_for: Dict[str, ScheduleEvent] = {}
        if plan:
            for ev in plan.events:
                self.by_trigger.setdefault(ev.trigger_op, []).append(ev)
                if ev.event_type is EventType.RECOMPUTE:
                    self.recompute_for[self._st(ev.tensor_id)] = ev
        self.producer: Dict[str, int] = {}
        for i, eqn in enumerate(self.jaxpr.eqns):
            for v in eqn.outvars:
                self.producer[self._name_of(v)] = i
        self.outvar_names = {self._name_of(v) for v in self.jaxpr.outvars
                             if not _is_dropvar(v)
                             and not isinstance(v, jcore.Literal)}
        self.stats = ExecutionStats(op_latencies=[] if measure_latency else None)
        self._cur_idx = -1

    # ------------------------------------------------------------------
    def _name_of(self, v) -> str:
        if v not in self._name:
            nm = f"v{len(self._name)}"
            self._name[v] = nm
            self.var_by_name[nm] = v
        return self._name[v]

    def _st(self, name: str) -> str:
        return self.storage.get(name, name)

    def _put_device(self, name: str, val: Any) -> None:
        st = self._st(name)
        if st in self.device:
            self.device[st] = val  # in-place overwrite (aliased update)
            return
        self.device[st] = val
        self.accountant.alloc(self.sizes.get(st, _arr_bytes(val)))

    def _drop_device(self, name: str) -> None:
        st = self._st(name)
        if st in self.device:
            val = self.device.pop(st)
            self.accountant.free(self.sizes.get(st, _arr_bytes(val)))

    def _get(self, name: str):
        return self.device.get(self._st(name))

    # ------------------------------------------------------------------
    def _swap_out(self, name: str) -> None:
        st = self._st(name)
        if st not in self.device:
            return
        val = self.device[st]

        def do():
            self.host[st] = np.asarray(val)  # real data path

        if self.async_exec:
            done = self.async_exec.submit("out:" + st, do)
            done.wait()  # eviction frees only after the copy lands (paper)
        else:
            self.channel.transfer(do)
        self._drop_device(st)
        self.stats.swap_out_count += 1

    def _swap_in(self, name: str, passive: bool) -> bool:
        """Prefetch from host; returns False when there is nothing to fetch
        (e.g. iteration-0 cold start of a cross-iteration plan)."""
        st = self._st(name)
        if st in self.device:
            return True
        if st not in self.host:
            return False

        def do():
            self._put_device(st, jax.numpy.asarray(self.host[st]))

        if self.async_exec and not passive:
            self.async_exec.submit("in:" + st, do)
        else:
            t0 = _time.perf_counter()
            self.channel.transfer(do)
            if passive:
                self.stats.passive_swap_ins += 1
                self.stats.stall_time_s += _time.perf_counter() - t0
        self.stats.swap_in_count += 1
        return True

    def _ensure_input(self, name: str) -> None:
        """An operator needs `name` now: prefetch-wait, passive swap-in, or
        recompute from the producer equation (paper Executor semantics)."""
        st = self._st(name)
        if st in self.device:
            return
        if self.async_exec and ("in:" + st) in self.async_exec.inflight:
            ts = _time.perf_counter()
            self.async_exec.inflight["in:" + st].wait()
            self.stats.stall_time_s += _time.perf_counter() - ts
            if st in self.device:
                return
        if self._swap_in(st, passive=True):
            return
        self._recompute(name)

    def _recompute(self, name: str) -> None:
        eqn_idx = self.producer.get(name)
        if eqn_idx is None:
            raise KeyError(f"tensor {name} unavailable and has no producer")
        eqn = self.jaxpr.eqns[eqn_idx]
        invals = []
        for v in eqn.invars:
            if isinstance(v, jcore.Literal):
                invals.append(v.val)
                continue
            nm = self._name_of(v)
            self._ensure_input(nm)
            invals.append(self._get(nm))
        outs = _eval_eqn(eqn, invals)
        for v, o in zip(eqn.outvars, outs):
            if not _is_dropvar(v):
                self._put_device(self._name_of(v), o)
        self.stats.recompute_count += 1

    # ------------------------------------------------------------------
    def run(self, *args: Any) -> Any:
        t_start = _time.perf_counter()
        flat, _ = jax.tree.flatten(args)
        assert len(flat) == len(self.jaxpr.invars), \
            f"expected {len(self.jaxpr.invars)} leaves, got {len(flat)}"
        for v, val in zip(self.jaxpr.invars, flat):
            nm = self._name_of(v)
            st = self._st(nm)
            if st in self.host_resident_inputs:
                # previous iteration parked this storage on host; it enters
                # the device only via its planned swap-in (or passively)
                self.host[st] = np.asarray(val)
            else:
                self._put_device(nm, val)
        for v, val in zip(self.jaxpr.constvars, self.closed.consts):
            self._put_device(self._name_of(v), val)

        for idx, eqn in enumerate(self.jaxpr.eqns):
            self._cur_idx = idx
            t0 = _time.perf_counter()
            invals = []
            for v in eqn.invars:
                if isinstance(v, jcore.Literal):
                    invals.append(v.val)
                    continue
                nm = self._name_of(v)
                self._ensure_input(nm)
                invals.append(self._get(nm))
            outs = _eval_eqn(eqn, invals)
            if self.measure_latency:
                jax.block_until_ready(outs)
                self.stats.op_latencies.append(_time.perf_counter() - t0)
            for v, o in zip(eqn.outvars, outs):
                if not _is_dropvar(v):
                    self._put_device(self._name_of(v), o)

            # releases: plan overrides, then free-at-last-use
            for v in list(eqn.invars) + list(eqn.outvars):
                if isinstance(v, jcore.Literal) or _is_dropvar(v):
                    continue
                nm = self._name_of(v)
                st = self._st(nm)
                spec = self.seq.tensors.get(nm)
                rel_op = (self.plan.release_after_op.get(nm)
                          if self.plan else None)
                if rel_op is not None and rel_op == idx:
                    self._drop_device(nm)
                    continue
                if (self.last_use.get(st) == idx
                        and (spec is None or (spec.kind not in PERSISTENT_KINDS
                                              and spec.updates is None))
                        and st not in self.outvar_names
                        and nm not in self.outvar_names):
                    self._drop_device(nm)

            # plan events triggered by this op
            for ev in self.by_trigger.get(idx, []):
                st = self._st(ev.tensor_id)
                if ev.event_type is EventType.SWAP_OUT:
                    self._swap_out(ev.tensor_id)
                elif ev.event_type is EventType.SWAP_IN:
                    # no-op on cold start (nothing on host yet)
                    self._swap_in(ev.tensor_id, passive=False)
                elif ev.event_type is EventType.RELEASE:
                    # only release when a host copy or a recompute plan can
                    # restore the value (paper Executor safety check)
                    if st in self.host or st in self.recompute_for:
                        self._drop_device(ev.tensor_id)
                elif ev.event_type is EventType.RECOMPUTE:
                    if st not in self.device:
                        self._recompute(ev.tensor_id)

        if self.async_exec:
            self.async_exec.drain()
        outs = []
        for v in self.jaxpr.outvars:
            if isinstance(v, jcore.Literal):
                outs.append(v.val)
                continue
            nm = self._name_of(v)
            if self._get(nm) is None:
                self._ensure_input(nm)
            outs.append(self._get(nm))
        self.stats.wall_time_s = _time.perf_counter() - t_start
        self.stats.peak_bytes = self.accountant.peak
        return outs

    # ------------------------------------------------------------------
    def ending_host_storages(self) -> Set[str]:
        """Storages left parked on host at iteration end (their device copy
        dropped) — the next iteration's `host_resident_inputs`."""
        return {st for st in self.host if st not in self.device}

    def close(self):
        if self.async_exec:
            self.async_exec.stop()


def _arr_bytes(x) -> int:
    try:
        return int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    except Exception:
        return 0


def _eval_eqn(eqn, invals: List[Any]) -> List[Any]:
    """Evaluate one jaxpr equation.  Call-like primitives run their
    sub-jaxpr through jaxpr_as_fun; everything else binds directly."""
    prim = eqn.primitive
    name = prim.name
    if name == "pjit":
        sub = eqn.params["jaxpr"]
        outs = jcore.jaxpr_as_fun(sub)(*invals)
        return list(outs)
    if name in ("custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
                "remat", "checkpoint"):
        sub = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr") \
            or eqn.params.get("jaxpr")
        if sub is not None:
            closed = sub if hasattr(sub, "consts") else jcore.ClosedJaxpr(sub, [])
            return list(jcore.jaxpr_as_fun(closed)(*invals))
    outs = prim.bind(*invals, **eqn.params)
    if not prim.multiple_results:
        outs = [outs]
    return list(outs)


def reference_outputs(closed_jaxpr, *args: Any) -> List[Any]:
    flat, _ = jax.tree.flatten(args)
    return list(jcore.jaxpr_as_fun(closed_jaxpr)(*flat))
