"""Core layers (pure JAX, no flax): norms, GLU MLPs, embeddings, init.

Every parameter initializer returns ``(param, logical_axes)`` so the
distribution layer can map logical axis names ("embed", "heads", "mlp",
"vocab", "experts", …) onto mesh axes without the model knowing about
meshes.  Activation sharding constraints go through `constrain`, a no-op
until `launch.sharding` installs rules.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------------
# Activation-sharding context (installed by launch.sharding.use_rules()).
# ----------------------------------------------------------------------
_ACTIVE_RULES = None


def set_active_rules(rules) -> None:
    global _ACTIVE_RULES
    _ACTIVE_RULES = rules


def constrain(x, logical: Tuple[Optional[str], ...]):
    """Constrain an activation to the mesh mapping of `logical` axes."""
    if _ACTIVE_RULES is None:
        return x
    return _ACTIVE_RULES.constrain(x, logical)


# ----------------------------------------------------------------------
# Param initialization.  A "param tree" is a dict pytree; alongside it we
# build an identically-shaped "axes tree" of logical-axis tuples.
# ----------------------------------------------------------------------
def dense_init(key, shape: Sequence[int], axes: Tuple[Optional[str], ...],
               dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    p = (jax.random.normal(key, tuple(shape), jnp.float32) * scale).astype(dtype)
    return p, tuple(axes)


def zeros_init(shape: Sequence[int], axes: Tuple[Optional[str], ...], dtype):
    return jnp.zeros(tuple(shape), dtype), tuple(axes)


def ones_init(shape: Sequence[int], axes: Tuple[Optional[str], ...], dtype):
    return jnp.ones(tuple(shape), dtype), tuple(axes)


class ParamBuilder:
    """Collects (params, axes) trees with a split-as-you-go PRNG key.

    `abstract=True` builds ShapeDtypeStructs instead of arrays — used by the
    multi-pod dry-run, which must never allocate full-size parameters.
    """

    def __init__(self, key, dtype, abstract: bool = False):
        self.key = key
        self.dtype = dtype
        self.abstract = abstract
        self.params: Dict[str, Any] = {}
        self.axes: Dict[str, Any] = {}

    def _next(self):
        if self.abstract:
            return None
        self.key, k = jax.random.split(self.key)
        return k

    def _emit(self, name, shape, axes, maker):
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        else:
            self.params[name] = maker()
        self.axes[name] = tuple(axes)

    def dense(self, name: str, shape, axes, scale=None):
        self._emit(name, shape, axes,
                   lambda: dense_init(self._next(), shape, axes, self.dtype,
                                      scale)[0])

    def zeros(self, name: str, shape, axes):
        self._emit(name, shape, axes,
                   lambda: jnp.zeros(tuple(shape), self.dtype))

    def ones(self, name: str, shape, axes):
        self._emit(name, shape, axes,
                   lambda: jnp.ones(tuple(shape), self.dtype))

    def sub(self, name: str, builder: "ParamBuilder"):
        self.params[name] = builder.params
        self.axes[name] = builder.axes

    def child(self) -> "ParamBuilder":
        return ParamBuilder(self._next(), self.dtype, self.abstract)


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------
def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------
# MLP (GLU family)
# ----------------------------------------------------------------------
def init_mlp(b: ParamBuilder, d_model: int, d_ff: int, act: str):
    gated = act in ("swiglu", "geglu")
    b.dense("wi", (d_model, d_ff), ("embed", "mlp"))
    if gated:
        b.dense("wg", (d_model, d_ff), ("embed", "mlp"))
    b.dense("wo", (d_ff, d_model), ("mlp", "embed"))


def mlp_apply(p, x, act: str):
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if act == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        h = jax.nn.silu(g) * h
    elif act == "geglu":
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        h = jax.nn.gelu(g, approximate=True) * h
    elif act == "relu2":  # squared ReLU (Primer / nemotron family)
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h, approximate=True)
    h = constrain(h, ("dp", None, "tp"))
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ----------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------
def init_embedding(b: ParamBuilder, vocab: int, d_model: int, tie: bool):
    # table: rows FSDP-sharded, d over the model axis (gather stays local
    # on the model axis; DESIGN.md §4)
    b.dense("tok", (vocab, d_model), ("vocab_gather", "embed_tp"), scale=1.0)
    if not tie:
        b.dense("head", (d_model, vocab), ("embed", "vocab"))


def embed_tokens(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p, x, tie: bool):
    if tie:
        # Reshard the (small) table — vocab to the model axis, d replicated —
        # instead of letting GSPMD reshard the (huge) logits: the tied table
        # is FSDP-sharded (vocab over data) for the gather, which conflicts
        # with batch-over-data logits. ~1 GB table move vs ~10s of GB of
        # logits movement (EXPERIMENTS.md §Perf, gemma hillclimb G1).
        w = constrain(p["tok"], ("vocab", None))
        return jnp.einsum("...d,vd->...v", x, w)
    return jnp.einsum("...d,dv->...v", x, p["head"])


# ----------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Loss
# ----------------------------------------------------------------------
def softmax_cross_entropy(logits, labels, z_loss: float = 0.0):
    """Token-mean CE; fp32 logsumexp; labels < 0 are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    ce = lse - gold
    if z_loss:
        ce = ce + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def fused_unembed_cross_entropy(embed_params, x, labels, tie: bool,
                                chunk: int = 2048):
    """LM-head + CE fused over sequence chunks: the (tokens × vocab) fp32
    logits tensor never materializes — each chunk's logits are produced,
    reduced to (lse, gold) and rematerialized in the backward
    (beyond-paper §Perf lever; the compiled analogue of TENSILE swapping
    the logits, except the tensor simply never exists)."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    pad = -s % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    xc = x.reshape(b, nc, chunk, d).swapaxes(0, 1)       # (nc,B,C,d)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    def chunk_loss(carry, inp):
        tot, cnt = carry
        xb, lb = inp
        logits = unembed(embed_params, xb, tie).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        mask = (lb >= 0).astype(jnp.float32)
        return (tot + jnp.sum((lse - gold) * mask),
                cnt + jnp.sum(mask)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(chunk_loss, prevent_cse=False),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)
