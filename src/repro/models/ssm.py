"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD forward: within a chunk the recurrence is computed as a masked
matmul (the "dual" quadratic form, MXU-friendly); across chunks a small
sequential scan carries the (H, P, N) state.  Decode is the O(1) recurrent
update — this is why `long_500k` runs for SSM archs.

Projections are **separate GEMMs per component** (z, x, B, C, dt) rather
than one fused in_proj: the fused output splits at boundaries that are not
multiples of the tensor-parallel shard size, which would force GSPMD to
all-gather a (tokens × 33k) tensor per layer (jamba).  Separate GEMMs give
each component its natural sharding (dinner → "model", B/C replicated,
heads → "model") with zero resharding.  Fusing them back is a recorded
single-device optimization, not a distribution win (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .layers import ParamBuilder, constrain, rmsnorm


def mamba_dims(cfg) -> Dict[str, int]:
    dinner = cfg.ssm_expand * cfg.d_model
    nheads = dinner // cfg.ssm_head_dim
    return dict(dinner=dinner, nheads=nheads, headdim=cfg.ssm_head_dim,
                nstate=cfg.ssm_state, conv_w=cfg.ssm_conv_width)


def init_mamba2(b: ParamBuilder, cfg):
    dm = mamba_dims(cfg)
    d, dinner, h, n, w = (cfg.d_model, dm["dinner"], dm["nheads"],
                          dm["nstate"], dm["conv_w"])
    b.dense("wz", (d, dinner), ("embed", "ssm_inner"))
    b.dense("wx", (d, dinner), ("embed", "ssm_inner"))
    b.dense("wb", (d, n), ("embed", None))
    b.dense("wc", (d, n), ("embed", None))
    b.dense("wdt", (d, h), ("embed", "ssm_heads"))
    b.dense("conv_wx", (w, dinner), (None, "ssm_inner"), scale=0.5)
    b.zeros("conv_bx", (dinner,), ("ssm_inner",))
    b.dense("conv_wb", (w, n), (None, None), scale=0.5)
    b.zeros("conv_bb", (n,), (None,))
    b.dense("conv_wc", (w, n), (None, None), scale=0.5)
    b.zeros("conv_bc", (n,), (None,))
    b.zeros("a_log", (h,), ("ssm_heads",))
    b.ones("d_skip", (h,), ("ssm_heads",))
    b.zeros("dt_bias", (h,), ("ssm_heads",))
    b.ones("norm_scale", (dinner,), ("ssm_inner",))
    b.dense("out_proj", (dinner, d), ("ssm_inner", "embed"))


def _causal_conv(u, w, b, state=None):
    """u: (B,S,C); w: (W,C) depthwise.  Returns (silu(out), new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(width))
    new_state = full[:, -(width - 1):] if width > 1 else pad
    return jax.nn.silu(out + b), new_state


def _project(p, x, cfg, conv_state=None):
    """x: (B,S,d) -> z, xh, bb, cc, dt (+ new conv states)."""
    dm = mamba_dims(cfg)
    z = jnp.einsum("bsd,di->bsi", x, p["wz"])
    xc = jnp.einsum("bsd,di->bsi", x, p["wx"])
    bb = jnp.einsum("bsd,dn->bsn", x, p["wb"])
    cc = jnp.einsum("bsd,dn->bsn", x, p["wc"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    cs = conv_state or {}
    xc, s_x = _causal_conv(xc, p["conv_wx"], p["conv_bx"], cs.get("x"))
    bb, s_b = _causal_conv(bb, p["conv_wb"], p["conv_bb"], cs.get("b"))
    cc, s_c = _causal_conv(cc, p["conv_wc"], p["conv_bc"], cs.get("c"))
    new_cs = {"x": s_x, "b": s_b, "c": s_c}
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return z, xc, bb, cc, dt, new_cs


def _segsum(dA):
    """log-space cumulative decay: L[i,j] = sum_{j<k<=i} dA_k (i>=j)."""
    s = jnp.cumsum(dA, axis=-1)
    diff = s[..., :, None] - s[..., None, :]
    q = dA.shape[-1]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, a, bb, cc, chunk: int, initial_state=None,
                use_kernel: bool = False):
    """SSD scan.

    xh: (B,S,H,P) value heads; dt: (B,S,H) (post-softplus);
    a: (H,) negative decay rates; bb/cc: (B,S,N).
    Returns y: (B,S,H,P), final_state: (B,H,P,N).
    """
    b, s, h, p = xh.shape
    n = bb.shape[-1]
    q = min(chunk, s)
    pad = -s % q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // q
    xc = xh.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    bc = bb.reshape(b, nc, q, n)
    ccx = cc.reshape(b, nc, q, n)

    dA = dtc * a[None, None, None, :]                      # (B,nc,Q,H) <= 0
    dA_cum = jnp.cumsum(dA, axis=2)
    dA_total = dA_cum[:, :, -1]                            # (B,nc,H)

    if use_kernel:
        from repro.kernels.ops import ssd_intra_chunk
        y_diag, states = ssd_intra_chunk(xc, dtc, dA, bc, ccx)
    else:
        # blocked over chunks: only one chunk's (B,H,Q,Q) decay/score tile
        # is live at a time — the jnp mirror of the Pallas kernel's VMEM
        # tiling (materializing all tiles is O(B·nc·H·Q²) = TBs at 4k+).
        # Heads stay a vectorized (tensor-parallel-sharded) dimension.
        def tile(args):
            da_t, dt_t, x_t, b_t, c_t = args
            # da_t/dt_t: (B,Q,H); x_t: (B,Q,H,P); b_t/c_t: (B,Q,N)
            cum = jnp.cumsum(da_t, axis=1)                 # (B,Q,H)
            diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,K,H)
            mask = jnp.tril(jnp.ones((q, q), bool))
            lm = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
            sc = jnp.einsum("bqn,bkn->bqk", c_t, b_t)      # (B,Q,K)
            y_t = jnp.einsum("bqk,bqkh,bkh,bkhp->bqhp",
                             sc, lm, dt_t, x_t)
            dec_end = jnp.exp(cum[:, -1:, :] - cum) * dt_t  # (B,Q,H)
            st_t = jnp.einsum("bqh,bqn,bqhp->bhpn", dec_end, b_t, x_t)
            return y_t, st_t

        ys, sts = jax.lax.map(
            jax.checkpoint(tile, prevent_cse=False),
            (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dtc, 1, 0),
             jnp.moveaxis(xc, 1, 0), jnp.moveaxis(bc, 1, 0),
             jnp.moveaxis(ccx, 1, 0)))
        y_diag = jnp.moveaxis(ys, 0, 1)                    # (B,nc,Q,H,P)
        states = jnp.moveaxis(sts, 0, 1)                   # (B,nc,H,P,N)

    # inter-chunk recurrence (sequential over chunks)
    chunk_decay = jnp.exp(dA_total)                        # (B,nc,H)
    if initial_state is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        h0 = initial_state.astype(jnp.float32)

    def scan_step(st, inp):
        s_c, dec = inp
        out_prev = st
        st = st * dec[..., None, None] + s_c
        return st, out_prev

    states_seq = jnp.moveaxis(states.astype(jnp.float32), 1, 0)   # (nc,B,H,P,N)
    decay_seq = jnp.moveaxis(chunk_decay, 1, 0)                   # (nc,B,H)
    final, prev_states = jax.lax.scan(scan_step, h0, (states_seq, decay_seq))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                 # (B,nc,H,P,N)

    in_decay = jnp.exp(dA_cum)                                    # (B,nc,Q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", ccx, in_decay, prev_states)
    y = (y_diag + y_off).reshape(b, nc * q, h, p)
    return y[:, :s].astype(xh.dtype), final


def mamba2_block(p, x, cfg, conv_state=None, ssm_state=None,
                 return_state: bool = False):
    """Full Mamba-2 mixer.  x: (B,S,d)."""
    dm = mamba_dims(cfg)
    z, xc, bb, cc, dt, _ = _project(p, x, cfg, conv_state)
    h, pd = dm["nheads"], dm["headdim"]
    xh = xc.reshape(*xc.shape[:-1], h, pd)
    xh = constrain(xh, ("dp", None, "tp", None))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, final_state = ssd_chunked(xh, dt, a, bb.astype(jnp.float32),
                                 cc.astype(jnp.float32), cfg.ssm_chunk,
                                 initial_state=ssm_state,
                                 use_kernel=cfg.use_flash_kernel)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(*y.shape[:-2], dm["dinner"])
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    if return_state:
        return out, final_state
    return out


# ----------------------------------------------------------------------
# Decode (recurrent, O(1) per token)
# ----------------------------------------------------------------------
def init_ssm_cache(batch: int, cfg, dtype) -> Dict[str, Any]:
    dm = mamba_dims(cfg)
    w = dm["conv_w"] - 1
    return {
        "conv_x": jnp.zeros((batch, w, dm["dinner"]), dtype),
        "conv_b": jnp.zeros((batch, w, dm["nstate"]), dtype),
        "conv_c": jnp.zeros((batch, w, dm["nstate"]), dtype),
        "state": jnp.zeros((batch, dm["nheads"], dm["headdim"],
                            dm["nstate"]), jnp.float32),
    }


def ssm_cache_axes() -> Dict[str, Any]:
    return {"conv_x": ("dp", None, "tp"),
            "conv_b": ("dp", None, None),
            "conv_c": ("dp", None, None),
            "state": ("dp", "tp", None, None)}


def mamba2_decode_step(p, x, cache, cfg):
    """x: (B,1,d); cache: {conv_x, conv_b, conv_c, state}."""
    dm = mamba_dims(cfg)
    conv_state = {"x": cache["conv_x"], "b": cache["conv_b"],
                  "c": cache["conv_c"]}
    z, xc, bb, cc, dt, new_cs = _project(p, x, cfg, conv_state)
    h, pd = dm["nheads"], dm["headdim"]
    xh = xc[:, 0].reshape(x.shape[0], h, pd)               # (B,H,P)
    dt1 = dt[:, 0]                                          # (B,H) fp32
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt1 * a[None, :])                        # (B,H)
    outer = jnp.einsum("bh,bn,bhp->bhpn", dt1,
                       bb[:, 0].astype(jnp.float32),
                       xh.astype(jnp.float32))
    state = cache["state"] * dec[..., None, None] + outer
    y = jnp.einsum("bn,bhpn->bhp", cc[:, 0].astype(jnp.float32), state)
    y = y + xh.astype(jnp.float32) \
        * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(x.shape[0], 1, dm["dinner"]).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"conv_x": new_cs["x"], "conv_b": new_cs["b"],
                 "conv_c": new_cs["c"], "state": state}
