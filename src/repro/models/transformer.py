"""Decoder LM supporting every assigned architecture family.

Structure: optional unscanned `prefix` layers, then `n_repeats` copies of a
`block` (a tuple of LayerSpecs) applied under `jax.lax.scan` with
layer-stacked parameters (MaxText-style — keeps HLO size and compile time
independent of depth).  Hybrid archs (jamba) interleave mamba/attn mixers
and dense/moe FFNs *inside* the block; pure archs have a single-layer block.

All parameters carry logical sharding axes (see layers.ParamBuilder);
activation constraints use logical names resolved by launch.sharding.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attention_block, decode_attention_block,
                        init_attention, init_kv_cache, kv_cache_axes)
from .layers import (ParamBuilder, constrain, embed_tokens, init_embedding,
                     init_mlp, mlp_apply, rmsnorm, softmax_cross_entropy,
                     unembed)
from .moe import init_moe, moe_apply
from .ssm import (init_mamba2, init_ssm_cache, mamba2_block,
                  mamba2_decode_step, ssm_cache_axes)


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------
def _init_layer(b: ParamBuilder, spec, cfg, d_ff: Optional[int] = None):
    b.ones("ln1", (cfg.d_model,), ("embed",))
    if spec.mixer == "attn":
        c = b.child()
        init_attention(c, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.head_dim, cfg.qkv_bias)
        b.sub("attn", c)
    else:
        c = b.child()
        init_mamba2(c, cfg)
        b.sub("mamba", c)
    if spec.ffn != "none":
        b.ones("ln2", (cfg.d_model,), ("embed",))
        c = b.child()
        if spec.ffn == "moe":
            init_moe(c, cfg.d_model, cfg.n_experts, cfg.moe_d_ff,
                     cfg.mlp_act, cfg.n_shared_experts)
            b.sub("moe", c)
        else:
            init_mlp(c, cfg.d_model, d_ff or cfg.d_ff, cfg.mlp_act)
            b.sub("mlp", c)


def _init_superblock(key, cfg, abstract: bool = False) -> Tuple[Dict, Dict]:
    b = ParamBuilder(key, jnp.dtype(cfg.dtype), abstract=abstract)
    for i, spec in enumerate(cfg.block):
        c = b.child()
        _init_layer(c, spec, cfg)
        b.sub(f"layer{i}", c)
    return b.params, b.axes


def _build_model(cfg, key, abstract: bool) -> Tuple[Dict, Dict]:
    b = ParamBuilder(key, jnp.dtype(cfg.dtype), abstract=abstract)
    c = b.child()
    init_embedding(c, cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings)
    b.sub("embed", c)
    for i, spec in enumerate(cfg.prefix):
        c = b.child()
        _init_layer(c, spec, cfg, d_ff=cfg.prefix_d_ff or cfg.d_ff)
        b.sub(f"prefix{i}", c)

    _, block_axes = _init_superblock(None, cfg, abstract=True)
    if abstract:
        one, _ = _init_superblock(None, cfg, abstract=True)
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_repeats,) + s.shape,
                                           s.dtype), one)
    else:
        keys = jax.random.split(b._next(), cfg.n_repeats)
        stacked = jax.vmap(lambda k: _init_superblock(k, cfg)[0])(keys)
    b.params["blocks"] = stacked
    b.axes["blocks"] = jax.tree.map(
        lambda a: ("layers",) + tuple(a), block_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    b.ones("final_norm", (cfg.d_model,), ("embed",))
    return b.params, b.axes


def init_model(cfg, key) -> Tuple[Dict, Dict]:
    """Concrete parameters + logical axes (smoke tests, examples)."""
    return _build_model(cfg, key, abstract=False)


def abstract_model(cfg) -> Tuple[Dict, Dict]:
    """ShapeDtypeStruct parameters + logical axes (dry-run: no allocation)."""
    return _build_model(cfg, None, abstract=True)


# ----------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------
def _apply_layer(p, spec, x, positions, cfg, aux):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        mix = attention_block(p["attn"], h, positions, cfg=cfg)
    else:
        mix = mamba2_block(p["mamba"], h, cfg)
    x = x + mix
    x = constrain(x, ("dp", "seq", None))
    if spec.ffn != "none":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "moe":
            ff, a = moe_apply(p["moe"], h, cfg)
            aux = aux + a
        else:
            ff = mlp_apply(p["mlp"], h, cfg.mlp_act)
        x = x + ff
        x = constrain(x, ("dp", "seq", None))
    return x, aux


def _apply_superblock(p, x, positions, cfg, aux):
    for i, spec in enumerate(cfg.block):
        x, aux = _apply_layer(p[f"layer{i}"], spec, x, positions, cfg, aux)
    return x, aux


def _backbone(params, tokens, cfg, *, extra_embeds=None,
              remat_policy=None) -> Tuple[Any, Any]:
    """Everything up to (and including) the final norm: (hidden, aux)."""
    x = embed_tokens(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    seq = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                 x.shape[:2])
    x = constrain(x, ("dp", "seq", None))
    aux = jnp.zeros((), jnp.float32)

    for i, spec in enumerate(cfg.prefix):
        x, aux = _apply_layer(params[f"prefix{i}"], spec, x, positions,
                              cfg, aux)

    block_fn = functools.partial(_apply_superblock, cfg=cfg)

    def body(carry, p_rep):
        x, aux = carry
        x, aux = block_fn(p_rep, x, positions, aux=aux)
        return (x, aux), None

    if remat_policy is not None:
        body = jax.checkpoint(body, policy=remat_policy,
                              prevent_cse=False)
    elif cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])

    return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def forward(params, tokens, cfg, *, extra_embeds=None,
            remat_policy=None) -> Tuple[Any, Any]:
    """tokens: (B,S_txt) int32; extra_embeds: (B,S_extra,d) stub-frontend
    embeddings prepended (pixtral patches / whisper handled in whisper.py).
    Returns (logits, aux_loss)."""
    x, aux = _backbone(params, tokens, cfg, extra_embeds=extra_embeds,
                       remat_policy=remat_policy)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    logits = constrain(logits, ("dp", None, "tp"))
    return logits, aux


def loss_fn(params, batch, cfg, remat_policy=None):
    if cfg.loss_chunk:
        from .layers import fused_unembed_cross_entropy
        x, aux = _backbone(params, batch["tokens"], cfg,
                           extra_embeds=batch.get("extra_embeds"),
                           remat_policy=remat_policy)
        labels = batch["labels"]
        if x.shape[1] != labels.shape[1]:
            x = x[:, -labels.shape[1]:]
        ce = fused_unembed_cross_entropy(params["embed"], x, labels,
                                         cfg.tie_embeddings,
                                         chunk=cfg.loss_chunk)
        return ce + 0.01 * aux
    logits, aux = forward(params, batch["tokens"], cfg,
                          extra_embeds=batch.get("extra_embeds"),
                          remat_policy=remat_policy)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, -labels.shape[1]:]  # drop frontend positions
    ce = softmax_cross_entropy(logits, labels)
    return ce + 0.01 * aux


# ----------------------------------------------------------------------
# Decode (serve path)
# ----------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int) -> Tuple[Dict, Dict]:
    """(cache, logical_axes) for one-token decode against max_len context."""
    dtype = jnp.dtype(cfg.dtype)

    def layer_cache(spec):
        if spec.mixer == "attn":
            return (init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                  cfg.head_dim, dtype), kv_cache_axes())
        return (init_ssm_cache(batch, cfg, dtype), ssm_cache_axes())

    cache: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    for i, spec in enumerate(cfg.prefix):
        cache[f"prefix{i}"], axes[f"prefix{i}"] = layer_cache(spec)

    blk_cache, blk_axes = {}, {}
    for i, spec in enumerate(cfg.block):
        c, a = layer_cache(spec)
        blk_cache[f"layer{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_repeats,) + x.shape), c)
        blk_axes[f"layer{i}"] = jax.tree.map(
            lambda t: ("layers",) + tuple(t), a,
            is_leaf=lambda x: isinstance(x, tuple))
    cache["blocks"] = blk_cache
    axes["blocks"] = blk_axes
    return cache, axes


def _decode_layer(p, spec, x, cache, index, cfg):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        mix, new_cache = decode_attention_block(p["attn"], h, cache, index,
                                                cfg=cfg)
    else:
        mix, new_cache = mamba2_decode_step(p["mamba"], h, cache, cfg)
    x = x + mix
    if spec.ffn != "none":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "moe":
            ff, _ = moe_apply(p["moe"], h, cfg)
        else:
            ff = mlp_apply(p["mlp"], h, cfg.mlp_act)
        x = x + ff
    return x, new_cache


def decode_step(params, cfg, tokens, cache, index):
    """One decode step.  tokens: (B,1) int32; index: int32 scalar position.
    Returns (logits (B,1,V), new_cache)."""
    x = embed_tokens(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))

    new_cache: Dict[str, Any] = {}
    for i, spec in enumerate(cfg.prefix):
        x, new_cache[f"prefix{i}"] = _decode_layer(
            params[f"prefix{i}"], spec, x, cache[f"prefix{i}"], index, cfg)

    def body(carry, scanned):
        x = carry
        p_rep, c_rep = scanned
        outs = {}
        for i, spec in enumerate(cfg.block):
            x, outs[f"layer{i}"] = _decode_layer(
                p_rep[f"layer{i}"], spec, x, c_rep[f"layer{i}"], index, cfg)
        return x, outs

    x, blocks_cache = jax.lax.scan(body, x,
                                   (params["blocks"], cache["blocks"]))
    new_cache["blocks"] = blocks_cache

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return logits, new_cache
