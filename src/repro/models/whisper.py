"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv frontend is a STUB per the assignment: `input_specs()` supplies
precomputed frame embeddings (B, S_enc, d_model).  The transformer backbone
is exact: bidirectional encoder stack, causal decoder stack with
cross-attention, both scanned over layers.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .attention import (attention_block, cross_attention_block,
                        decode_attention_block, init_attention,
                        init_kv_cache, kv_cache_axes)
from .layers import (ParamBuilder, constrain, embed_tokens, init_embedding,
                     init_mlp, mlp_apply, rmsnorm, softmax_cross_entropy,
                     unembed)


def _init_enc_layer(b: ParamBuilder, cfg):
    b.ones("ln1", (cfg.d_model,), ("embed",))
    c = b.child()
    init_attention(c, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                   cfg.head_dim, cfg.qkv_bias)
    b.sub("attn", c)
    b.ones("ln2", (cfg.d_model,), ("embed",))
    c = b.child()
    init_mlp(c, cfg.d_model, cfg.d_ff, cfg.mlp_act)
    b.sub("mlp", c)


def _init_dec_layer(b: ParamBuilder, cfg):
    _init_enc_layer(b, cfg)  # ln1+self attn, ln2+mlp
    b.ones("ln_x", (cfg.d_model,), ("embed",))
    c = b.child()
    init_attention(c, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                   cfg.head_dim, cfg.qkv_bias)
    b.sub("xattn", c)


def _stacked(cfg, init_one, n: int, key, abstract: bool):
    def build(k):
        b = ParamBuilder(k, jnp.dtype(cfg.dtype), abstract=abstract)
        init_one(b, cfg)
        return b.params, b.axes

    _, axes = build(None) if abstract else build(jax.random.PRNGKey(0))
    axes = jax.tree.map(
        lambda a: ("layers",) + tuple(a), axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    if abstract:
        one, _ = build(None)
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), one)
    else:
        params = jax.vmap(lambda k: build(k)[0])(jax.random.split(key, n))
    return params, axes


def build_whisper(cfg, key, abstract: bool) -> Tuple[Dict, Dict]:
    b = ParamBuilder(key, jnp.dtype(cfg.dtype), abstract=abstract)
    c = b.child()
    init_embedding(c, cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings)
    b.sub("embed", c)
    kk = (None, None) if abstract else jax.random.split(b._next())
    b.params["enc_blocks"], b.axes["enc_blocks"] = _stacked(
        cfg, _init_enc_layer, cfg.n_enc_layers, kk[0], abstract)
    b.params["dec_blocks"], b.axes["dec_blocks"] = _stacked(
        cfg, _init_dec_layer, cfg.n_layers, kk[1], abstract)
    b.ones("enc_norm", (cfg.d_model,), ("embed",))
    b.ones("final_norm", (cfg.d_model,), ("embed",))
    return b.params, b.axes


def init_whisper(cfg, key):
    return build_whisper(cfg, key, abstract=False)


def abstract_whisper(cfg):
    return build_whisper(cfg, None, abstract=True)


# ----------------------------------------------------------------------
def encode(params, audio_feats, cfg):
    """audio_feats: (B, S_enc, d) stub frontend embeddings."""
    x = audio_feats.astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])

    def body(x, p):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = x + attention_block(p["attn"], h, positions, cfg=cfg,
                                causal=False)
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg.mlp_act)
        return constrain(x, ("dp", None, None)), None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, tokens, enc_out, cfg):
    x = embed_tokens(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])

    def body(x, p):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = x + attention_block(p["attn"], h, positions, cfg=cfg, causal=True)
        h = rmsnorm(x, p["ln_x"], cfg.norm_eps)
        x = x + cross_attention_block(p["xattn"], h, enc_out, cfg=cfg)
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg.mlp_act)
        return constrain(x, ("dp", None, None)), None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params["embed"], x, cfg.tie_embeddings)


def forward(params, batch, cfg):
    enc_out = encode(params, batch["audio_feats"], cfg)
    logits = decode_train(params, batch["tokens"], enc_out, cfg)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, remat_policy=None):
    logits, _ = forward(params, batch, cfg)
    return softmax_cross_entropy(logits, batch["labels"])


# ----------------------------------------------------------------------
# Decode serving: cached self-attention + precomputed cross K/V
# ----------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int) -> Tuple[Dict, Dict]:
    dtype = jnp.dtype(cfg.dtype)
    one = init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim, dtype)
    cache = {"self": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)}
    axes = {"self": jax.tree.map(
        lambda t: ("layers",) + tuple(t), kv_cache_axes(),
        is_leaf=lambda x: isinstance(x, tuple))}
    return cache, axes


def decode_step(params, cfg, tokens, cache, index, enc_out):
    """One decoder token against cached self-KV + encoder output."""
    x = embed_tokens(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))

    def body(x, scanned):
        p, c = scanned
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        mix, new_c = decode_attention_block(p["attn"], h, c, index, cfg=cfg)
        x = x + mix
        h = rmsnorm(x, p["ln_x"], cfg.norm_eps)
        x = x + cross_attention_block(p["xattn"], h, enc_out, cfg=cfg)
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg.mlp_act)
        return x, new_c

    x, new_self = jax.lax.scan(body, x, (params["dec_blocks"], cache["self"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.tie_embeddings)
    return logits, {"self": new_self}
