"""Uniform model API over every architecture family.

    api = get_model(cfg)
    params, axes = api.init(key)            # or api.abstract_params()
    loss = api.loss(params, batch)
    logits, aux = api.forward(params, batch)
    cache, cache_axes = api.init_cache(batch, max_len)
    logits, cache = api.decode(params, batch, cache, index)
    batch = api.input_specs(shape_spec, abstract=True)

`input_specs` follows the assignment: ``decode_*``/``long_*`` build a
one-new-token batch against a seq_len-deep cache; ``[audio]``/``[vlm]``
stub frontends supply precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from . import transformer, whisper


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _concrete(batch_specs, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, s in batch_specs.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, 32, jnp.int32)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)
    return out


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    abstract_params: Callable
    loss: Callable
    forward: Callable
    init_cache: Callable
    abstract_cache: Callable
    decode: Callable
    input_specs: Callable
    decode_input_specs: Callable


# ----------------------------------------------------------------------
def _lm_api(cfg: ModelConfig) -> ModelAPI:
    def input_specs(shape: ShapeSpec, abstract: bool = True,
                    per_device_batch: Optional[int] = None):
        b = per_device_batch or shape.global_batch
        s = shape.seq_len
        dt = cfg.dtype
        if cfg.frontend == "vision_stub":
            n_txt = s - cfg.n_patches
            specs = {"tokens": _spec((b, n_txt), jnp.int32),
                     "labels": _spec((b, n_txt), jnp.int32),
                     "extra_embeds": _spec((b, cfg.n_patches, cfg.d_model), dt)}
        else:
            specs = {"tokens": _spec((b, s), jnp.int32),
                     "labels": _spec((b, s), jnp.int32)}
        if shape.kind == "prefill":
            specs.pop("labels")
        return specs if abstract else _concrete(specs)

    def decode_input_specs(shape: ShapeSpec, abstract: bool = True,
                           per_device_batch: Optional[int] = None):
        b = per_device_batch or shape.global_batch
        specs = {"tokens": _spec((b, 1), jnp.int32)}
        if cfg.frontend == "vision_stub":
            specs["extra_embeds"] = _spec((b, 0, cfg.d_model), cfg.dtype)
        return specs if abstract else _concrete(specs)

    def loss(params, batch, remat_policy=None):
        return transformer.loss_fn(params, batch, cfg,
                                   remat_policy=remat_policy)

    def fwd(params, batch):
        return transformer.forward(params, batch["tokens"], cfg,
                                   extra_embeds=batch.get("extra_embeds"))

    def init_cache(batch: int, max_len: int):
        return transformer.init_cache(cfg, batch, max_len)

    def abstract_cache(batch: int, max_len: int):
        cache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, batch, max_len)[0])
        _, axes = transformer.init_cache(cfg, 1, 1)
        return cache, axes

    def decode(params, batch, cache, index):
        return transformer.decode_step(params, cfg, batch["tokens"], cache,
                                       index)

    return ModelAPI(
        cfg=cfg,
        init=lambda key: transformer.init_model(cfg, key),
        abstract_params=lambda: transformer.abstract_model(cfg),
        loss=loss, forward=fwd,
        init_cache=init_cache, abstract_cache=abstract_cache,
        decode=decode, input_specs=input_specs,
        decode_input_specs=decode_input_specs)


# ----------------------------------------------------------------------
def _whisper_api(cfg: ModelConfig) -> ModelAPI:
    def input_specs(shape: ShapeSpec, abstract: bool = True,
                    per_device_batch: Optional[int] = None):
        b = per_device_batch or shape.global_batch
        s_enc = shape.seq_len
        s_dec = max(shape.seq_len // cfg.enc_seq_ratio, 8)
        specs = {"audio_feats": _spec((b, s_enc, cfg.d_model), cfg.dtype),
                 "tokens": _spec((b, s_dec), jnp.int32),
                 "labels": _spec((b, s_dec), jnp.int32)}
        if shape.kind == "prefill":
            specs.pop("labels")
        return specs if abstract else _concrete(specs)

    def decode_input_specs(shape: ShapeSpec, abstract: bool = True,
                           per_device_batch: Optional[int] = None):
        b = per_device_batch or shape.global_batch
        s_enc = max(shape.seq_len // cfg.enc_seq_ratio, 8)
        specs = {"tokens": _spec((b, 1), jnp.int32),
                 "enc_out": _spec((b, s_enc, cfg.d_model), cfg.dtype)}
        return specs if abstract else _concrete(specs)

    def loss(params, batch, remat_policy=None):
        return whisper.loss_fn(params, batch, cfg, remat_policy=remat_policy)

    def decode(params, batch, cache, index):
        return whisper.decode_step(params, cfg, batch["tokens"], cache,
                                   index, batch["enc_out"])

    return ModelAPI(
        cfg=cfg,
        init=lambda key: whisper.init_whisper(cfg, key),
        abstract_params=lambda: whisper.abstract_whisper(cfg),
        loss=loss,
        forward=lambda params, batch: whisper.forward(params, batch, cfg),
        init_cache=lambda b, m: whisper.init_cache(cfg, b, m),
        abstract_cache=lambda b, m: (
            jax.eval_shape(lambda: whisper.init_cache(cfg, b, m)[0]),
            whisper.init_cache(cfg, 1, 1)[1]),
        decode=decode, input_specs=input_specs,
        decode_input_specs=decode_input_specs)


def get_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.enc_dec:
        return _whisper_api(cfg)
    return _lm_api(cfg)
