"""Attention: GQA/MQA + RoPE, with three execution paths.

* `attend_full`    — plain einsum attention (small seqs, smoke tests).
* `attend_chunked` — memory-efficient online-softmax over KV chunks in pure
  jnp (lax.scan): never materializes the (S×S) score tensor.  This is the
  TENSILE insight applied structurally on TPU — the tensor the paper would
  swap is simply never allocated (DESIGN.md §2).
* Pallas flash kernel (kernels/flash_attention.py) — TPU target, selected
  with cfg.use_flash_kernel; validated in interpret mode by tests.

Decode: one-token query against a (possibly sequence-sharded) KV cache.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import ParamBuilder, apply_rope, constrain

NEG_INF = -1e30


def init_attention(b: ParamBuilder, d_model: int, n_heads: int,
                   n_kv_heads: int, head_dim: int, qkv_bias: bool):
    b.dense("wq", (d_model, n_heads, head_dim), ("embed", "heads", None))
    b.dense("wk", (d_model, n_kv_heads, head_dim), ("embed", "kv_heads", None))
    b.dense("wv", (d_model, n_kv_heads, head_dim), ("embed", "kv_heads", None))
    b.dense("wo", (n_heads, head_dim, d_model), ("heads", None, "embed"))
    if qkv_bias:
        b.zeros("bq", (n_heads, head_dim), ("heads", None))
        b.zeros("bk", (n_kv_heads, head_dim), ("kv_heads", None))
        b.zeros("bv", (n_kv_heads, head_dim), ("kv_heads", None))


def _project_qkv(p, x, positions, theta):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def _group_heads(q, n_kv_heads):
    """(B,S,H,D) -> (B,S,KV,G,D) splitting query heads into KV groups."""
    b, s, h, d = q.shape
    g = h // n_kv_heads
    return q.reshape(b, s, n_kv_heads, g, d)


def attend_full(q, k, v, *, causal: bool, q_offset: int = 0,
                sliding_window: int = 0):
    """q: (B,Sq,H,D); k,v: (B,Skv,KV,D).  Returns (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    qg = _group_heads(q, kvh)                      # B,Sq,KV,G,D
    scale = 1.0 / np.sqrt(d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    skv = k.shape[1]
    if causal or sliding_window:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(skv)[None, :]
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= kpos <= qpos
        if sliding_window:
            mask &= kpos > qpos - sliding_window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, d)


def _repeat_kv(k, h):
    """Broadcast KV heads to the full query-head count.  The (KV,G) grouped
    form defeats tensor-parallel head sharding whenever KV < tp (the 8×8
    reshape of kimi's 64 heads cannot map onto a 16-way axis and GSPMD
    re-gathers); the repeated form shards (B,S,H,D) cleanly and costs only
    the small repeated K/V reads — it is what flash kernels do anyway."""
    kvh = k.shape[2]
    if kvh == h:
        return k
    return jnp.repeat(k, h // kvh, axis=2)


def attend_chunked(q, k, v, *, causal: bool, chunk: int = 1024,
                   sliding_window: int = 0):
    """Online-softmax attention, scanning KV chunks per Q chunk.

    Peak score tile is (B,H,Cq,Ckv) — independent of total seq length.
    Dots run on the native (bf16) operands with fp32 accumulation
    (`preferred_element_type`): no fp32 upcast of Q/K/V tensors.
    """
    b, sq, h, d = q.shape
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    k = constrain(k, ("dp", None, "tp", None))
    v = constrain(v, ("dp", None, "tp", None))
    cq = min(chunk, sq)
    ckv = min(chunk, k.shape[1])
    sq_pad = -sq % cq
    skv = k.shape[1]
    skv_pad = -skv % ckv
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0)))
    if skv_pad:
        k = jnp.pad(k, ((0, 0), (0, skv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_pad), (0, 0), (0, 0)))
    nq = (sq + sq_pad) // cq
    nk = (skv + skv_pad) // ckv
    qg = q.reshape(b, nq, cq, h, d)
    kc = k.reshape(b, nk, ckv, h, d)
    vc = v.reshape(b, nk, ckv, h, d)
    scale = np.float32(1.0 / np.sqrt(d))

    kpos_all = jnp.arange(nk * ckv).reshape(nk, ckv)
    valid_k = (kpos_all < skv)

    def q_block(qi, qblk):
        # qblk: (B,Cq,H,D)
        qpos = qi * cq + jnp.arange(cq)

        def kv_step(carry, inp):
            m, l, acc = carry
            kblk, vblk, kpos, kvalid = inp
            s = jnp.einsum("bqhd,bshd->bhqs", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = kvalid[None, :]
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if sliding_window:
                mask = mask & (kpos[None, :] > qpos[:, None] - sliding_window)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, d), jnp.float32)
        # checkpoint each kv step: the (Cq×Ckv) probability tile is
        # recomputed in the backward instead of being saved per step —
        # the flash-backward memory behaviour, in pure jnp
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step, prevent_cse=False), (m0, l0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), kpos_all, valid_k))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # (B,H,Cq,D)

    outs = jax.lax.map(lambda i: q_block(i, qg[:, i]), jnp.arange(nq))
    # (nq,B,H,Cq,D) -> (B, nq*Cq, H, D)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 3, 2, 4)
    out = out.reshape(b, nq * cq, h, d)
    return out[:, :sq].astype(q.dtype)


def attention_block(p, x, positions, *, cfg, causal: bool = True,
                    use_chunked: Optional[bool] = None):
    """Self-attention over x: (B,S,D_model)."""
    q, k, v = _project_qkv(p, x, positions, cfg.rope_theta)
    q = constrain(q, ("dp", None, "tp", None))
    k = constrain(k, ("dp", None, "tp_kv", None))
    v = constrain(v, ("dp", None, "tp_kv", None))
    if use_chunked is None:
        use_chunked = x.shape[1] > 2 * cfg.attn_chunk
    if cfg.use_flash_kernel and causal:
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, k, v, causal=True,
                              sliding_window=cfg.sliding_window)
    elif use_chunked:
        out = attend_chunked(q, k, v, causal=causal, chunk=cfg.attn_chunk,
                             sliding_window=cfg.sliding_window)
    else:
        out = attend_full(q, k, v, causal=causal,
                          sliding_window=cfg.sliding_window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attention_block(p, x, ctx, *, cfg):
    """Decoder cross-attention: queries from x, keys/values from ctx."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", ctx, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", ctx, p["wv"])
    if max(q.shape[1], k.shape[1]) > 2 * cfg.attn_chunk:
        out = attend_chunked(q, k, v, causal=False, chunk=cfg.attn_chunk)
    else:
        out = attend_full(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ----------------------------------------------------------------------
# Decode path (KV cache)
# ----------------------------------------------------------------------
def init_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  dtype) -> Dict[str, Any]:
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
    }


def kv_cache_axes() -> Dict[str, Any]:
    # sequence-sharded cache: attention decode reduces over the sharded seq
    # axis (flash-decoding style; XLA inserts the combine collectives)
    return {"k": ("dp", "kv_seq", None, None), "v": ("dp", "kv_seq", None, None)}


def decode_attention_block(p, x, cache, index, *, cfg):
    """x: (B,1,D); cache k/v: (B,max_len,KV,D); index: current position."""
    positions = jnp.full((x.shape[0], 1), index, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, positions, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(
        cache["k"].dtype), index, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(
        cache["v"].dtype), index, axis=1)
    b, s, kvh, d = k.shape
    qg = _group_heads(q, kvh)                                  # B,1,KV,G,D
    scale = 1.0 / np.sqrt(d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    kpos = jnp.arange(s)[None, None, None, None, :]
    mask = kpos <= index
    if cfg.sliding_window:
        mask = mask & (kpos > index - cfg.sliding_window)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    out = out.reshape(b, 1, qg.shape[2] * qg.shape[3], d).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k, "v": v}
