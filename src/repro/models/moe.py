"""Mixture-of-Experts FFN with expert parallelism.

Two implementations:
* `scatter` (default, scales to kimi-k2's 384 experts) — GShard-style
  capacity dispatch realized with a sort-free rank computation and a
  scatter into an `(E, C, d)` buffer that shards cleanly over the expert
  axis (EP on the "model" mesh axis); expert GEMMs are batched einsums so
  GSPMD partitions them without all-gathering tokens.  FLOPs are
  `E·C·d·f ≈ capacity_factor × active FLOPs` — no dense-dispatch blowup.
* `dense` — every expert on every token, einsum-combined; O(E) FLOPs, used
  only by reduced smoke configs and as the numerical reference in tests.

Router: softmax top-k with normalized weights + the standard load-balance
auxiliary loss (Switch/GShard).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamBuilder, constrain


def init_moe(b: ParamBuilder, d_model: int, n_experts: int, d_ff: int,
             act: str, n_shared: int = 0):
    gated = act in ("swiglu", "geglu")
    b.dense("router", (d_model, n_experts), ("embed", None))
    b.dense("wi", (n_experts, d_model, d_ff), ("experts", "embed", None))
    if gated:
        b.dense("wg", (n_experts, d_model, d_ff), ("experts", "embed", None))
    b.dense("wo", (n_experts, d_ff, d_model), ("experts", None, "embed"))
    if n_shared:
        b.dense("shared_wi", (d_model, n_shared * d_ff), ("embed", "mlp"))
        if gated:
            b.dense("shared_wg", (d_model, n_shared * d_ff), ("embed", "mlp"))
        b.dense("shared_wo", (n_shared * d_ff, d_model), ("mlp", "embed"))


def _expert_ffn(p, h_in, act: str):
    """h_in: (E, C, d) -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", h_in, p["wi"])
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", h_in, p["wg"])
        h = jax.nn.silu(g) * h
    elif act == "geglu":
        g = jnp.einsum("ecd,edf->ecf", h_in, p["wg"])
        h = jax.nn.gelu(g, approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def _router(p, x2d, top_k: int):
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)          # (T,k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch eq. 4): E * sum_e f_e * P_e
    e = logits.shape[-1]
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = e * jnp.sum(me * ce)
    return weights, experts, aux


def moe_apply_scatter(p, x, *, top_k: int, n_experts: int,
                      capacity_factor: float, act: str) -> Tuple[Any, Any]:
    """x: (B,S,d) -> (B,S,d), aux_loss."""
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    weights, experts, aux = _router(p, x2d, top_k)

    srows = t * top_k
    expert_flat = experts.reshape(srows)                     # token-major
    w_flat = weights.reshape(srows).astype(x.dtype)
    token_idx = jnp.repeat(jnp.arange(t), top_k)

    capacity = int(max(1, round(t * top_k / n_experts * capacity_factor)))
    capacity = -(-capacity // 128) * 128  # align slots for sharding/MXU
    # rank of each row within its expert via a global sort (O(S log S)
    # memory O(S) — a (S,E) one-hot cumsum would be terabytes at kimi's
    # 384 experts × 8M rows)
    order = jnp.argsort(expert_flat)
    sorted_e = expert_flat[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[expert_flat].add(1)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(srows, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((srows,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < capacity
    slot = jnp.where(keep, rank, capacity)                   # C = drop row

    # dispatch: (E, C+1, d) buffer — experts over the model axis (EP),
    # capacity over the data axes, so expert GEMM work stays balanced
    # across the whole mesh instead of idling the data axis
    rows = jnp.where(keep[:, None], x2d[token_idx], 0).astype(x.dtype)
    buf = jnp.zeros((n_experts, capacity + 1, d), x.dtype)
    buf = buf.at[expert_flat, slot].add(rows)
    buf = constrain(buf, ("ep", "cap", None))

    out_e = _expert_ffn(p, buf[:, :capacity], act)
    out_e = jnp.pad(out_e, ((0, 0), (0, 1), (0, 0)))
    out_e = constrain(out_e, ("ep", "cap", None))

    # combine
    gathered = out_e[expert_flat, slot] * (w_flat * keep)[:, None]
    y = jnp.sum(gathered.reshape(t, top_k, d), axis=1)

    if "shared_wi" in p:
        y = y + _shared_ffn(p, x2d, act)
    return y.reshape(b, s, d), aux


def moe_apply_dense(p, x, *, top_k: int, n_experts: int, act: str):
    """Reference path: run every expert on every token (tiny configs only)."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    weights, experts, aux = _router(p, x2d, top_k)
    h = jnp.einsum("td,edf->tef", x2d, p["wi"])
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("td,edf->tef", x2d, p["wg"])
        gate = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = gate * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    out_all = jnp.einsum("tef,efd->ted", h, p["wo"])         # (T,E,d)
    mask = jnp.zeros((x2d.shape[0], n_experts), x.dtype)
    tok = jnp.arange(x2d.shape[0])[:, None]
    mask = mask.at[tok, experts].add(weights.astype(x.dtype))
    y = jnp.einsum("ted,te->td", out_all, mask)
    if "shared_wi" in p:
        y = y + _shared_ffn(p, x2d, act)
    return y.reshape(b, s, d), aux


def _shared_ffn(p, x2d, act: str):
    h = jnp.einsum("td,df->tf", x2d, p["shared_wi"])
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("td,df->tf", x2d, p["shared_wg"])
        gate = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = gate * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("tf,fd->td", h, p["shared_wo"])


def moe_apply_a2a(p, x, *, top_k: int, n_experts: int,
                  capacity_factor: float, act: str) -> Tuple[Any, Any]:
    """Expert parallelism with explicit all-to-all dispatch (shard_map).

    GSPMD lowers the global scatter/gather dispatch of `moe_apply_scatter`
    into partial-sum ALL-REDUCES of the full (rows × d) dispatch tensor —
    ~30 GB/device/layer on kimi-k2 (EXPERIMENTS.md §Perf K-baseline).  The
    production pattern instead keeps dispatch local per shard and moves
    only the (E, C_local, d) buffer through one all-to-all each way:

        local top-k/rank/scatter → all_to_all(E→E/tp, C→tp·C) →
        local expert GEMMs (weights FSDP-gathered) → all_to_all back →
        local combine.

    Requires an active mesh (launch.sharding rules); falls back to the
    scatter path on a single device.
    """
    from repro.models import layers as _L
    rules = _L._ACTIVE_RULES
    mesh = getattr(rules, "mesh", None)
    if mesh is None or "model" not in mesh.axis_names \
            or mesh.shape["model"] == 1 or n_experts % mesh.shape["model"]:
        return moe_apply_scatter(p, x, top_k=top_k, n_experts=n_experts,
                                 capacity_factor=capacity_factor, act=act)
    from jax.sharding import PartitionSpec as P
    tp = mesh.shape["model"]
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]

    b, s, d = x.shape
    # static local token count per (dp, tp) shard (seq over model)
    b_loc = b // n_dp if b % n_dp == 0 else b
    s_loc = s // tp if s % tp == 0 else s
    t_loc = b_loc * s_loc
    cap = int(max(1, round(t_loc * top_k / n_experts * capacity_factor)))
    cap = -(-cap // 8) * 8
    gated = act in ("swiglu", "geglu")

    x_spec = P(dp_axes if b % n_dp == 0 else None,
               "model" if s % tp == 0 else None, None)

    def block(x_l, router, wi, wg, wo):
        tl = x_l.shape[0] * x_l.shape[1]
        x2d = x_l.reshape(tl, d)
        weights, experts, aux = _router({"router": router}, x2d, top_k)
        aux = jax.lax.pmean(aux, "model")
        for ax in dp_axes:
            aux = jax.lax.pmean(aux, ax)
        srows = tl * top_k
        e_flat = experts.reshape(srows)
        w_flat = weights.reshape(srows).astype(x_l.dtype)
        token_idx = jnp.repeat(jnp.arange(tl), top_k)
        order = jnp.argsort(e_flat)
        counts = jnp.zeros((n_experts,), jnp.int32).at[e_flat].add(1)
        starts = jnp.cumsum(counts) - counts
        rank_sorted = jnp.arange(srows, dtype=jnp.int32) \
            - starts[e_flat[order]]
        rank = jnp.zeros((srows,), jnp.int32).at[order].set(rank_sorted)
        keep = rank < cap
        slot = jnp.where(keep, rank, cap)
        rows = jnp.where(keep[:, None], x2d[token_idx], 0).astype(x_l.dtype)
        buf = jnp.zeros((n_experts, cap + 1, d), x_l.dtype)
        buf = buf.at[e_flat, slot].add(rows)[:, :cap]

        # dispatch: experts to their shard, capacities concatenated
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)          # (E/tp, tp*cap, d)
        # FSDP weight gather (d is sharded over the data axes)
        for ax in dp_axes:
            wi = jax.lax.all_gather(wi, ax, axis=1, tiled=True)
            if wg is not None:
                wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, ax, axis=2, tiled=True)
        h = jnp.einsum("ecd,edf->ecf", buf, wi)
        if gated:
            g = jnp.einsum("ecd,edf->ecf", buf, wg)
            h = (jax.nn.silu(g) if act == "swiglu"
                 else jax.nn.gelu(g, approximate=True)) * h
        else:
            h = jax.nn.gelu(h, approximate=True)
        out = jnp.einsum("ecf,efd->ecd", h, wo)       # (E/tp, tp*cap, d)
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                 tiled=True)          # (E, cap, d)
        out = jnp.pad(out, ((0, 0), (0, 1), (0, 0)))
        gathered = out[e_flat, slot] * (w_flat * keep)[:, None]
        y = jnp.sum(gathered.reshape(tl, top_k, d), axis=1)
        return y.reshape(x_l.shape), aux

    wg_arg = p.get("wg")
    # weights are FSDP-stored: declare their true layout so shard_map does
    # not gather them up front (we gather inside, per layer)
    wi_spec = P("model", dp_axes, None)
    wo_spec = P("model", None, dp_axes)
    y, aux = jax.shard_map(
        block, mesh=mesh,
        in_specs=(x_spec, P(None, None), wi_spec,
                  (wi_spec if gated else P()), wo_spec),
        out_specs=(x_spec, P()),
        check_vma=False)(
        x, p["router"], p["wi"],
        (wg_arg if gated else jnp.zeros((), x.dtype)), p["wo"])
    if "shared_wi" in p:
        y = y + _shared_ffn(p, x.reshape(b * s, d), act).reshape(x.shape)
    return y, aux


def moe_apply(p, x, cfg) -> Tuple[Any, Any]:
    kwargs = dict(top_k=cfg.top_k, n_experts=cfg.n_experts, act=cfg.mlp_act)
    if cfg.moe_impl == "dense":
        return moe_apply_dense(p, x, **kwargs)
    if cfg.moe_impl == "a2a":
        return moe_apply_a2a(p, x, capacity_factor=cfg.capacity_factor,
                             **kwargs)
    return moe_apply_scatter(p, x, capacity_factor=cfg.capacity_factor,
                             **kwargs)
