"""The observability plane: a unified view over the telemetry plane.

Three coordinated pieces (ISSUE 10):

- :class:`TraceRecorder` — a structured span/instant/counter event stream
  tapped from the ``TelemetryHub`` / ``MemoryEngine`` / ``DmaChannel`` /
  simulator / executor / serving / daemon hooks, exported as Chrome Trace
  Event Format JSON (loadable in ``chrome://tracing`` or Perfetto).
- :class:`MetricsRegistry` — counters / gauges / histograms exposed by the
  scheduler daemon as a Prometheus text-format file refreshed with the
  heartbeat.
- :class:`DriftMonitor` — the sim-vs-measured accuracy watchdog: compares
  predicted peak/EOR/safe-point placement against measured values per
  fingerprint, emits drift gauges + WARN events past a threshold, and
  persists per-fingerprint drift history into the ``ExperienceStore``.

Every producer-side hook is ZERO-overhead when no recorder is attached:
one ``is not None`` check on an attribute that defaults to ``None`` —
the same discipline as the DMA channel's ``coalesce=False`` default.
"""
from .events import Event, EventLog
from .drift import DriftMonitor, DriftSample
from .metrics import MetricsRegistry, parse_metrics_text
from .trace import (TRACE_SCHEMA_VERSION, TraceRecorder, format_summary,
                    load_trace, summarize_trace, validate_chrome_trace)

__all__ = [
    "Event", "EventLog",
    "DriftMonitor", "DriftSample",
    "MetricsRegistry", "parse_metrics_text",
    "TRACE_SCHEMA_VERSION", "TraceRecorder", "format_summary", "load_trace",
    "summarize_trace", "validate_chrome_trace",
]
