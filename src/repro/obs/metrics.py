"""Prometheus-style metrics: in-process registry + text exposition.

The daemon owns one registry, refreshes its gauges with every heartbeat
and atomically rewrites ``metrics.prom`` next to ``daemon.json`` — any
scrape-by-file collector (node_exporter textfile, a cron'd curl
substitute) picks it up.  ``parse_metrics_text`` is the symmetric
reader, used by the round-trip test and the ``tensile_svc.py metrics``
CLI.

Naming convention: every metric is ``tensile_<noun>_<unit>`` (bytes,
seconds, total for counters, ratio for 0..1 gauges); labels identify
the job / state / fingerprint, never the metric meaning.
"""
from __future__ import annotations

import math
import os
import tempfile
import threading
from typing import Dict, Iterable, List, Optional, Tuple

LabelSet = Tuple[Tuple[str, str], ...]

DEFAULT_BUCKETS = (0.005, 0.025, 0.1, 0.5, 2.5, 10.0, 60.0)


def _labels(kw: Dict[str, object]) -> LabelSet:
    return tuple(sorted((k, str(v)) for k, v in kw.items()))


def _fmt_labels(ls: LabelSet, extra: Optional[Tuple[str, str]] = None) -> str:
    items = list(ls) + ([extra] if extra else [])
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    if v != v:
        return "NaN"
    if v in (math.inf, -math.inf):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help_text: str = ""):
        self.name = name
        self.help = help_text
        self._lock = threading.Lock()


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name: str, help_text: str = ""):
        super().__init__(name, help_text)
        self._values: Dict[LabelSet, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        ls = _labels(labels)
        with self._lock:
            self._values[ls] = self._values.get(ls, 0.0) + amount

    def value(self, **labels) -> float:
        return self._values.get(_labels(labels), 0.0)

    def samples(self) -> Iterable[Tuple[str, LabelSet, float]]:
        with self._lock:
            for ls, v in sorted(self._values.items()):
                yield self.name, ls, v


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, name: str, help_text: str = ""):
        super().__init__(name, help_text)
        self._values: Dict[LabelSet, float] = {}

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[_labels(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        ls = _labels(labels)
        with self._lock:
            self._values[ls] = self._values.get(ls, 0.0) + amount

    def value(self, **labels) -> float:
        return self._values.get(_labels(labels), 0.0)

    def clear(self) -> None:
        """Drop every label set (per-job gauges on job departure)."""
        with self._lock:
            self._values.clear()

    def samples(self) -> Iterable[Tuple[str, LabelSet, float]]:
        with self._lock:
            for ls, v in sorted(self._values.items()):
                yield self.name, ls, v


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help_text: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help_text)
        self.buckets = tuple(sorted(buckets))
        self._counts: Dict[LabelSet, List[int]] = {}
        self._sum: Dict[LabelSet, float] = {}
        self._count: Dict[LabelSet, int] = {}

    def observe(self, value: float, **labels) -> None:
        ls = _labels(labels)
        with self._lock:
            counts = self._counts.setdefault(ls, [0] * len(self.buckets))
            for i, b in enumerate(self.buckets):
                if value <= b:
                    counts[i] += 1
            self._sum[ls] = self._sum.get(ls, 0.0) + value
            self._count[ls] = self._count.get(ls, 0) + 1

    def count(self, **labels) -> int:
        return self._count.get(_labels(labels), 0)

    def sum(self, **labels) -> float:
        return self._sum.get(_labels(labels), 0.0)

    def samples(self) -> Iterable[Tuple[str, LabelSet, float]]:
        with self._lock:
            for ls in sorted(self._count):
                cum = 0
                for i, b in enumerate(self.buckets):
                    cum = self._counts[ls][i]
                    yield (f"{self.name}_bucket",
                           ls + (("le", _fmt_value(b)),), float(cum))
                yield (f"{self.name}_bucket", ls + (("le", "+Inf"),),
                       float(self._count[ls]))
                yield f"{self.name}_sum", ls, self._sum[ls]
                yield f"{self.name}_count", ls, float(self._count[ls])


class MetricsRegistry:
    """Idempotent factory + renderer for a process's metrics."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help_text: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help_text, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}")
            return m

    def counter(self, name: str, help_text: str = "") -> Counter:
        return self._get(Counter, name, help_text)

    def gauge(self, name: str, help_text: str = "") -> Gauge:
        return self._get(Gauge, name, help_text)

    def histogram(self, name: str, help_text: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help_text, buckets=buckets)

    # -- exposition -----------------------------------------------------
    def render_text(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: List[str] = []
        with self._lock:
            metrics = sorted(self._metrics.values(), key=lambda m: m.name)
        for m in metrics:
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for name, ls, v in m.samples():
                # histogram sample names carry the le label inline
                le = None
                plain = []
                for k, val in ls:
                    if k == "le":
                        le = ("le", val)
                    else:
                        plain.append((k, val))
                lines.append(f"{name}{_fmt_labels(tuple(plain), le)} "
                             f"{_fmt_value(v)}")
        return "\n".join(lines) + "\n"

    def write(self, path: str) -> str:
        """Atomically write the exposition file (heartbeat cadence)."""
        text = self.render_text()
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(text)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return text


def parse_metrics_text(text: str) -> Dict[Tuple[str, LabelSet], float]:
    """Parse Prometheus text exposition back into ``{(name, labels):
    value}``.  Raises ``ValueError`` on a malformed sample line, so it
    doubles as the schema validator for CI artifacts."""
    out: Dict[Tuple[str, LabelSet], float] = {}
    for ln, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name, labels, rest = line, (), ""
        if "{" in line:
            name, _, tail = line.partition("{")
            body, closed, rest = tail.partition("}")
            if not closed:
                raise ValueError(f"line {ln}: unterminated label set")
            parsed = []
            for item in filter(None, (p.strip()
                                      for p in body.split(","))):
                k, eq, v = item.partition("=")
                if not eq or not (v.startswith('"') and v.endswith('"')):
                    raise ValueError(f"line {ln}: bad label {item!r}")
                parsed.append((k.strip(), v[1:-1]))
            labels = tuple(sorted(parsed))
        else:
            name, _, rest = line.partition(" ")
        name = name.strip()
        if not name or not name.replace("_", "a").replace(":", "a") \
                .isalnum():
            raise ValueError(f"line {ln}: bad metric name {name!r}")
        val = rest.strip().split()[0] if rest.strip() else None
        if val is None:
            raise ValueError(f"line {ln}: missing value")
        try:
            fval = float(val)
        except ValueError as e:
            raise ValueError(f"line {ln}: bad value {val!r}") from e
        out[(name, labels)] = fval
    if not out:
        raise ValueError("no samples found")
    return out
