"""Chrome Trace Event Format export of a scheduling run.

The recorder taps the telemetry plane at its single publish point
(``TelemetryHub._publish``) plus a handful of instant hooks (plan
hot-swaps, DMA batch merges, daemon state transitions), buffers the raw
samples, and renders them to Chrome Trace Event Format JSON on demand —
one track per job, one DMA-channel track, per-job residency and
device-budget counter tracks.  Because both runtimes emit through the
same hub schemas, a virtual-time (simulator) trace and a wall-clock
(executor) trace of the same job + plan diff side-by-side.

Track layout (pid 1 = the device):

- ``tid 1..N`` — one per job (op spans, stall spans, hot-swap instants)
- ``tid 1000`` — the DMA channel (swap/prefetch spans, batch instants)
- ``tid 1001`` — structured events forwarded from an ``EventLog``
- counter tracks — ``resident:<job>``, ``device_used_bytes``,
  ``device_budget_bytes``

Timestamps: virtual-clock seconds (simulator) or hub-relative wall
seconds (executor), both scaled to microseconds and shifted so the
earliest event sits at ts=0 — the two clocks are distinguished only by
the ``otherData.clock`` field.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, List, Optional, Tuple

TRACE_SCHEMA_VERSION = 1

# fixed tids for the non-job tracks; job tracks allocate from 1 upward
DMA_TID = 1000
EVENTS_TID = 1001

_S_TO_US = 1e6


class TraceRecorder:
    """Buffer of structured trace events, rendered lazily by
    :meth:`to_chrome`.

    The hot-path surface is two tiny methods — :meth:`on_sample` (called
    under the hub lock from ``TelemetryHub._publish``) and
    :meth:`instant` — so an attached recorder costs one list append per
    record; an unattached one costs a single ``is not None`` check at
    each hook site.
    """

    def __init__(self, clock: str = "virtual",
                 budget_bytes: Optional[int] = None):
        self.clock = clock
        self.budget_bytes = budget_bytes
        # raw telemetry samples in publish order: (kind, sample)
        self.samples: List[Tuple[str, Any]] = []
        # extra structured events: dicts with a "ph"-like "type" key
        self.extras: List[Dict[str, Any]] = []
        self.meta: Dict[str, Any] = {}

    # -- producer hooks (hot path: keep these minimal) ------------------
    def on_sample(self, kind: str, s) -> None:
        """Tap point for ``TelemetryHub._publish`` (hub lock held)."""
        self.samples.append((kind, s))

    def instant(self, name: str, t: float, job_id: Optional[str] = None,
                **args) -> None:
        self.extras.append({"type": "instant", "name": name, "t": t,
                            "job_id": job_id, "args": args})

    def span(self, name: str, t: float, dur: float,
             job_id: Optional[str] = None, cat: str = "span",
             **args) -> None:
        self.extras.append({"type": "span", "name": name, "t": t,
                            "dur": dur, "job_id": job_id, "cat": cat,
                            "args": args})

    def counter(self, name: str, t: float, value: float) -> None:
        self.extras.append({"type": "counter", "name": name, "t": t,
                            "value": value})

    # -- export ---------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """Render the buffered stream as a Chrome Trace Event Format
        dict (``json.dump`` it, load in chrome://tracing or Perfetto)."""
        events: List[Dict[str, Any]] = []
        tids: Dict[str, int] = {}

        def job_tid(job_id: Optional[str]) -> int:
            key = f"job:{job_id}" if job_id is not None else "events"
            if key == "events":
                return EVENTS_TID
            if key not in tids:
                tids[key] = 1 + len(tids)
            return tids[key]

        # pass 1: translate samples/extras into (ts, event) with raw
        # second timestamps; shift to zero afterwards
        raw: List[Tuple[float, Dict[str, Any]]] = []
        # replay per-job residency to derive the device-wide used curve
        job_resident: Dict[str, int] = {}
        used_curve: List[Tuple[float, int]] = []

        for kind, s in self.samples:
            if kind == "op":
                ts = s.t - s.latency_s
                raw.append((ts, {
                    "name": s.prim or f"op{s.op_idx}", "cat": "op",
                    "ph": "X", "ts": ts, "dur": s.latency_s,
                    "pid": 1, "tid": job_tid(s.job_id),
                    "args": {"job": s.job_id, "op_idx": s.op_idx,
                             "iteration": s.iteration}}))
            elif kind == "transfer":
                raw.append((s.t, {
                    "name": f"{s.direction}:{s.storage}", "cat": "transfer",
                    "ph": "X", "ts": s.t, "dur": s.duration_s,
                    "pid": 1, "tid": DMA_TID,
                    "args": {"job": s.job_id, "storage": s.storage,
                             "direction": s.direction,
                             "size_bytes": s.size_bytes,
                             "compressed": s.compressed,
                             "passive": s.passive,
                             "iteration": s.iteration}}))
            elif kind == "stall":
                ts = s.t - s.duration_s
                raw.append((ts, {
                    "name": s.cause, "cat": "stall",
                    "ph": "X", "ts": ts, "dur": s.duration_s,
                    "pid": 1, "tid": job_tid(s.job_id),
                    "args": {"job": s.job_id, "op_idx": s.op_idx,
                             "iteration": s.iteration}}))
            else:  # residency
                raw.append((s.t, {
                    "name": f"resident:{s.job_id}", "cat": "residency",
                    "ph": "C", "ts": s.t, "pid": 1,
                    "args": {"bytes": s.resident_bytes}}))
                job_resident[s.job_id] = s.resident_bytes
                used_curve.append((s.t, sum(job_resident.values())))

        for t, used in used_curve:
            raw.append((t, {"name": "device_used_bytes", "cat": "residency",
                            "ph": "C", "ts": t, "pid": 1,
                            "args": {"bytes": used}}))

        for ev in self.extras:
            if ev["type"] == "instant":
                raw.append((ev["t"], {
                    "name": ev["name"], "cat": "event", "ph": "i",
                    "ts": ev["t"], "pid": 1, "tid": job_tid(ev["job_id"]),
                    "s": "t" if ev["job_id"] is not None else "g",
                    "args": dict(ev["args"])}))
            elif ev["type"] == "span":
                raw.append((ev["t"], {
                    "name": ev["name"], "cat": ev["cat"], "ph": "X",
                    "ts": ev["t"], "dur": ev["dur"],
                    "pid": 1, "tid": job_tid(ev["job_id"]),
                    "args": dict(ev["args"])}))
            else:  # counter
                raw.append((ev["t"], {
                    "name": ev["name"], "cat": "counter", "ph": "C",
                    "ts": ev["t"], "pid": 1,
                    "args": {"value": ev["value"]}}))

        t0 = min((t for t, _ in raw), default=0.0)
        t1 = max((t for t, _ in raw), default=0.0)

        # the device budget: a flat counter track bracketing the run,
        # plus a global instant at every upward crossing of used > budget
        if self.budget_bytes is not None:
            for t in (t0, t1):
                raw.append((t, {"name": "device_budget_bytes",
                                "cat": "counter", "ph": "C", "ts": t,
                                "pid": 1,
                                "args": {"bytes": int(self.budget_bytes)}}))
            over = False
            for t, used in used_curve:
                now_over = used > self.budget_bytes
                if now_over and not over:
                    raw.append((t, {"name": "budget_violation",
                                    "cat": "event", "ph": "i", "ts": t,
                                    "pid": 1, "tid": EVENTS_TID, "s": "g",
                                    "args": {"used_bytes": used,
                                             "budget_bytes":
                                                 int(self.budget_bytes)}}))
                over = now_over

        for _, ev in raw:
            ev["ts"] = round((ev["ts"] - t0) * _S_TO_US, 3)
            if "dur" in ev:
                ev["dur"] = round(max(ev["dur"], 0.0) * _S_TO_US, 3)
            events.append(ev)

        # metadata: process + thread names, emitted for every tid in use
        meta_events: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": 1,
            "args": {"name": f"tensile ({self.clock} clock)"}}]
        for key, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            meta_events.append({"name": "thread_name", "ph": "M", "pid": 1,
                                "tid": tid, "args": {"name": key}})
        if any(e.get("tid") == DMA_TID for e in events):
            meta_events.append({"name": "thread_name", "ph": "M", "pid": 1,
                                "tid": DMA_TID, "args": {"name": "dma"}})
        if any(e.get("tid") == EVENTS_TID for e in events):
            meta_events.append({"name": "thread_name", "ph": "M", "pid": 1,
                                "tid": EVENTS_TID, "args": {"name": "events"}})

        other = {"clock": self.clock, "schema": TRACE_SCHEMA_VERSION}
        other.update(self.meta)
        return {"traceEvents": meta_events + events,
                "displayTimeUnit": "ms",
                "otherData": other}

    def dump(self, path: str) -> Dict[str, Any]:
        """Atomically write the Chrome trace JSON; returns the dict."""
        trace = self.to_chrome()
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(trace, f, indent=1)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return trace


# ---------------------------------------------------------------- schema
_KNOWN_PH = {"X", "i", "I", "C", "M", "B", "E"}


def validate_chrome_trace(trace: Any) -> List[str]:
    """Validate a dict against the Chrome Trace Event Format subset the
    recorder emits.  Returns a list of error strings — empty means
    valid.  Strict enough that a malformed export can't slip into CI
    artifacts, loose enough to accept any viewer-loadable trace."""
    errs: List[str] = []
    if not isinstance(trace, dict):
        return [f"trace must be a JSON object, got {type(trace).__name__}"]
    evs = trace.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents must be a list"]
    if not evs:
        errs.append("traceEvents is empty")
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _KNOWN_PH:
            errs.append(f"{where}: unknown ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errs.append(f"{where}: missing name")
        if ph == "M":
            if ev["name"] not in ("process_name", "thread_name"):
                errs.append(f"{where}: metadata name {ev['name']!r}")
            elif not isinstance(ev.get("args", {}).get("name"), str):
                errs.append(f"{where}: metadata args.name missing")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"{where}: bad ts {ts!r}")
        if not isinstance(ev.get("pid"), int):
            errs.append(f"{where}: bad pid")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: complete event with bad dur {dur!r}")
            if not isinstance(ev.get("tid"), int):
                errs.append(f"{where}: complete event without tid")
        elif ph in ("i", "I"):
            if ev.get("s", "t") not in ("t", "p", "g"):
                errs.append(f"{where}: instant scope {ev.get('s')!r}")
        elif ph == "C":
            args = ev.get("args")
            if (not isinstance(args, dict) or not args
                    or not all(isinstance(v, (int, float))
                               for v in args.values())):
                errs.append(f"{where}: counter args must be numbers")
    return errs


def load_trace(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


# ---------------------------------------------------------------- summary
def summarize_trace(trace: Dict[str, Any], top: int = 5) -> Dict[str, Any]:
    """Distill a trace for humans: top swaps by duration, per-job stall
    share, budget-violation instants, and track inventory."""
    evs = [e for e in trace.get("traceEvents", [])
           if isinstance(e, dict) and e.get("ph") != "M"]
    transfers = [e for e in evs
                 if e.get("ph") == "X" and e.get("cat") == "transfer"]
    transfers.sort(key=lambda e: -e.get("dur", 0.0))
    ops: Dict[str, float] = {}
    stalls: Dict[str, float] = {}
    for e in evs:
        if e.get("ph") != "X":
            continue
        job = e.get("args", {}).get("job")
        if job is None:
            continue
        if e.get("cat") == "op":
            ops[job] = ops.get(job, 0.0) + e.get("dur", 0.0)
        elif e.get("cat") == "stall":
            stalls[job] = stalls.get(job, 0.0) + e.get("dur", 0.0)
    stall_share = {
        j: (stalls.get(j, 0.0) / (ops[j] + stalls.get(j, 0.0))
            if ops[j] + stalls.get(j, 0.0) > 0 else 0.0)
        for j in ops}
    violations = [e for e in evs if e.get("name") == "budget_violation"]
    hot_swaps = [e for e in evs if e.get("name") == "hot_swap"]
    counters = sorted({e["name"] for e in evs if e.get("ph") == "C"})
    return {
        "events": len(evs),
        "jobs": sorted(ops),
        "counters": counters,
        "top_swaps": [{"name": e["name"], "dur_us": e.get("dur", 0.0),
                       "ts_us": e.get("ts", 0.0),
                       "job": e.get("args", {}).get("job")}
                      for e in transfers[:top]],
        "transfer_count": len(transfers),
        "stall_share": stall_share,
        "budget_violations": [{"ts_us": e.get("ts", 0.0),
                               "used_bytes":
                                   e.get("args", {}).get("used_bytes")}
                              for e in violations],
        "hot_swaps": [{"ts_us": e.get("ts", 0.0),
                       "args": e.get("args", {})} for e in hot_swaps],
    }


def format_summary(summary: Dict[str, Any]) -> str:
    lines = [f"events: {summary['events']}  jobs: "
             f"{', '.join(summary['jobs']) or '-'}",
             f"counter tracks: {', '.join(summary['counters']) or '-'}",
             f"transfers: {summary['transfer_count']}"]
    if summary["top_swaps"]:
        lines.append("top swaps by duration:")
        for s in summary["top_swaps"]:
            lines.append(f"  {s['name']:<28} {s['dur_us']:>12.1f} us "
                         f"@ {s['ts_us']:.1f} us ({s['job']})")
    if summary["stall_share"]:
        lines.append("stall share:")
        for j, sh in sorted(summary["stall_share"].items()):
            lines.append(f"  {j:<28} {100 * sh:6.2f} %")
    lines.append(f"hot swaps: {len(summary['hot_swaps'])}")
    if summary["budget_violations"]:
        lines.append(f"budget violations: "
                     f"{len(summary['budget_violations'])}")
        for v in summary["budget_violations"]:
            lines.append(f"  over budget at {v['ts_us']:.1f} us "
                         f"(used {v['used_bytes']})")
    else:
        lines.append("budget violations: 0")
    return "\n".join(lines)
