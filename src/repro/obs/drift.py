"""Sim-vs-measured drift monitor.

The parity tests pin that the simulator and executor agree *in CI, on
one job, once*.  The monitor turns that into an always-on product
signal: every comparison of a predicted quantity (sim peak, sim EOR,
modeled safe-point placement) against its measured counterpart flows
through :meth:`DriftMonitor.observe`, which

- computes relative drift per quantity,
- sets per-fingerprint drift gauges on an attached
  :class:`~repro.obs.metrics.MetricsRegistry`,
- emits a WARN event on an attached
  :class:`~repro.obs.events.EventLog` past ``threshold``,
- and persists the sample into the ``ExperienceStore`` drift history
  (so xMem-style estimation accuracy becomes a tracked, per-workload
  time series, not a point assertion).

The scenario suite distills the monitor's output into the ``drift``
bench row gated by ``tools/check_bench_regression.py::drift_contract``.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Dict, List, Optional, Sequence

DEFAULT_THRESHOLD = 0.15
HISTORY_LIMIT = 64


def rel_drift(predicted: float, measured: float) -> float:
    """|predicted - measured| relative to measured (0 = perfect)."""
    if measured == 0:
        return 0.0 if predicted == 0 else 1.0
    return abs(float(predicted) - float(measured)) / abs(float(measured))


def safe_point_drift(predicted: Optional[Sequence[int]],
                     measured: Optional[Sequence[int]]) -> Optional[float]:
    """Placement disagreement between two safe-point sets: 1 - Jaccard
    over op indices.  0 = same placements, 1 = disjoint."""
    if predicted is None or measured is None:
        return None
    p, m = set(predicted), set(measured)
    if not p and not m:
        return 0.0
    return 1.0 - len(p & m) / len(p | m)


@dataclasses.dataclass
class DriftSample:
    fingerprint: str
    job_id: str
    t: float
    predicted_peak: int
    measured_peak: int
    peak_drift: float
    predicted_eor: Optional[float] = None
    measured_eor: Optional[float] = None
    eor_drift: Optional[float] = None
    sp_drift: Optional[float] = None

    @property
    def worst(self) -> float:
        return max([self.peak_drift]
                   + [d for d in (self.eor_drift, self.sp_drift)
                      if d is not None])


class DriftMonitor:
    def __init__(self, threshold: float = DEFAULT_THRESHOLD,
                 events=None, metrics=None, experience=None,
                 clock=None, history_limit: int = HISTORY_LIMIT):
        self.threshold = float(threshold)
        self.events = events
        self.metrics = metrics
        self.experience = experience
        self._clock = clock or _time.time
        self.history_limit = history_limit
        self._history: Dict[str, List[DriftSample]] = {}

    # -- the one producer entry point -----------------------------------
    def observe(self, fingerprint: str, *, predicted_peak: int,
                measured_peak: int, job_id: str = "",
                predicted_eor: Optional[float] = None,
                measured_eor: Optional[float] = None,
                predicted_safe_points: Optional[Sequence[int]] = None,
                measured_safe_points: Optional[Sequence[int]] = None,
                t: Optional[float] = None) -> DriftSample:
        eor_drift = (rel_drift(predicted_eor, measured_eor)
                     if predicted_eor is not None
                     and measured_eor is not None else None)
        s = DriftSample(
            fingerprint=fingerprint, job_id=job_id,
            t=self._clock() if t is None else t,
            predicted_peak=int(predicted_peak),
            measured_peak=int(measured_peak),
            peak_drift=rel_drift(predicted_peak, measured_peak),
            predicted_eor=predicted_eor, measured_eor=measured_eor,
            eor_drift=eor_drift,
            sp_drift=safe_point_drift(predicted_safe_points,
                                      measured_safe_points))
        hist = self._history.setdefault(fingerprint, [])
        hist.append(s)
        del hist[:-self.history_limit]

        fp_label = fingerprint[:12] if fingerprint else "unknown"
        if self.metrics is not None:
            g = self.metrics.gauge(
                "tensile_drift_peak_ratio",
                "relative |sim-predicted - measured| peak bytes")
            g.set(s.peak_drift, fingerprint=fp_label)
            if s.eor_drift is not None:
                self.metrics.gauge(
                    "tensile_drift_eor_ratio",
                    "relative |sim-predicted - measured| EOR").set(
                        s.eor_drift, fingerprint=fp_label)
            if s.sp_drift is not None:
                self.metrics.gauge(
                    "tensile_drift_safe_point_ratio",
                    "1 - Jaccard of modeled vs measured safe-point "
                    "placement").set(s.sp_drift, fingerprint=fp_label)
            self.metrics.counter(
                "tensile_drift_observations_total",
                "drift comparisons performed").inc(fingerprint=fp_label)

        if self.events is not None and s.worst > self.threshold:
            self.events.warn(
                "drift",
                f"sim-vs-measured drift {s.worst:.3f} exceeds threshold "
                f"{self.threshold:.3f} for fingerprint {fp_label}",
                fingerprint=fp_label, job_id=job_id,
                peak_drift=round(s.peak_drift, 6),
                eor_drift=(None if s.eor_drift is None
                           else round(s.eor_drift, 6)),
                sp_drift=(None if s.sp_drift is None
                          else round(s.sp_drift, 6)),
                predicted_peak=s.predicted_peak,
                measured_peak=s.measured_peak)

        if self.experience is not None and fingerprint:
            try:
                self.experience.record_drift(fingerprint, s)
            except Exception as e:  # noqa: BLE001 - monitoring must not kill
                if self.events is not None:
                    self.events.warn("drift",
                                     "persisting drift history failed",
                                     fingerprint=fp_label, error=repr(e))
        return s

    # -- consumers ------------------------------------------------------
    def history(self, fingerprint: str) -> List[DriftSample]:
        return list(self._history.get(fingerprint, []))

    def last(self, fingerprint: str) -> Optional[DriftSample]:
        hist = self._history.get(fingerprint)
        return hist[-1] if hist else None

    def worst_drift(self) -> float:
        """Max worst-axis drift over the latest sample per fingerprint."""
        latest = [h[-1].worst for h in self._history.values() if h]
        return max(latest, default=0.0)

    def over_threshold(self) -> List[DriftSample]:
        return [h[-1] for h in self._history.values()
                if h and h[-1].worst > self.threshold]
