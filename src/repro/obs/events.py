"""Structured event log: the WARN-and-above channel of the plane.

Failure paths that used to be silent list appends (the controller's
``experience_failures`` / ``replan_failures`` / ``preempt_failures``)
emit through here instead — bounded ring buffer, queryable by level and
source, forwarded to an attached :class:`TraceRecorder` as instant
events so a trace shows WHERE in the timeline persistence failed.
"""
from __future__ import annotations

import dataclasses
import threading
import time as _time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

LEVELS = ("DEBUG", "INFO", "WARN", "ERROR")


@dataclasses.dataclass
class Event:
    t: float
    level: str
    source: str
    message: str
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class EventLog:
    """Thread-safe bounded event stream.

    ``clock`` defaults to wall time; pass the hub's ``now`` (or a
    virtual clock) so event instants line up with telemetry timestamps
    in an exported trace.
    """

    def __init__(self, maxlen: int = 1024,
                 clock: Optional[Callable[[], float]] = None):
        self._events: Deque[Event] = deque(maxlen=maxlen)
        self._lock = threading.Lock()
        self._clock = clock or _time.time
        self.recorder = None           # optional TraceRecorder forward
        self.dropped = 0

    def attach_recorder(self, recorder) -> None:
        self.recorder = recorder

    def emit(self, level: str, source: str, message: str,
             **args) -> Event:
        assert level in LEVELS, level
        ev = Event(self._clock(), level, source, message, args)
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)
        rec = self.recorder
        if rec is not None:
            # args may carry its own job_id (controller WARNs do) — route
            # it to the recorder's track selector instead of colliding
            # with the keyword
            fwd = {k: v for k, v in args.items() if k != "job_id"}
            rec.instant(f"{level}:{source}", ev.t,
                        job_id=args.get("job_id"), message=message, **fwd)
        return ev

    def warn(self, source: str, message: str, **args) -> Event:
        return self.emit("WARN", source, message, **args)

    def info(self, source: str, message: str, **args) -> Event:
        return self.emit("INFO", source, message, **args)

    def error(self, source: str, message: str, **args) -> Event:
        return self.emit("ERROR", source, message, **args)

    def events(self, level: Optional[str] = None,
               source: Optional[str] = None) -> List[Event]:
        with self._lock:
            evs = list(self._events)
        if level is not None:
            evs = [e for e in evs if e.level == level]
        if source is not None:
            evs = [e for e in evs if e.source == source]
        return evs

    def warnings(self) -> List[Event]:
        """WARN and ERROR events, the "something needs a human" slice."""
        with self._lock:
            return [e for e in self._events if e.level in ("WARN", "ERROR")]

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)
