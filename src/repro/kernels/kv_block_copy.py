"""Batched KV-block gather/scatter: one launch moves a whole block set.

The serving plane's batched data path (`ServingEngine` under
``batch_transfers``) moves the cohort's ``kv/<rid>/b<i>`` regions per
decode turn.  The per-slot path costs one device launch per (leaf, slot);
these kernels move the *set* in a single launch over a 2-D row-pool view
of each cache leaf:

* ``kv_block_gather(pool, idx)``   -> ``pool[idx]``        (K, W)
* ``kv_block_scatter(pool, idx, blocks)`` -> pool with ``pool[idx]``
  replaced by ``blocks`` (in-place via ``input_output_aliases``)

Row indices arrive through a scalar-prefetch argument
(``pltpu.PrefetchScalarGridSpec``), so the block index maps are computed
before the kernel body runs — the TPU-idiomatic dynamic gather.  Pure
oracles live in ``kernels/ref.py`` (``kv_block_gather_ref`` /
``kv_block_scatter_ref``); ``interpret=True`` keeps the kernels runnable
on the CPU container.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(idx_ref, src_ref, out_ref):
    del idx_ref  # consumed by the index maps
    out_ref[...] = src_ref[...]


def kv_block_gather(pool, idx, *, interpret: bool = True):
    """Gather rows ``idx`` of a 2-D row pool in one launch: returns an
    array of shape ``(len(idx), pool.shape[1])``."""
    pool = jnp.asarray(pool)
    idx = jnp.asarray(idx, jnp.int32)
    k = idx.shape[0]
    n, w = pool.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[pl.BlockSpec((1, w), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, w), lambda i, idx_ref: (i, 0)))
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, w), pool.dtype),
        interpret=interpret)(idx, pool)


def _scatter_kernel(idx_ref, blocks_ref, pool_ref, out_ref):
    del idx_ref, pool_ref  # index maps / aliased initial value
    out_ref[...] = blocks_ref[...]


def kv_block_scatter(pool, idx, blocks, *, interpret: bool = True):
    """Scatter ``blocks`` (K, W) into rows ``idx`` of a 2-D row pool in
    one launch; rows not in ``idx`` keep their values (the pool buffer is
    aliased into the output)."""
    pool = jnp.asarray(pool)
    idx = jnp.asarray(idx, jnp.int32)
    blocks = jnp.asarray(blocks, pool.dtype)
    k = idx.shape[0]
    n, w = pool.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i, idx_ref: (i, 0)),        # blocks
            pl.BlockSpec((1, w), lambda i, idx_ref: (idx_ref[i], 0)),  # pool
        ],
        out_specs=pl.BlockSpec((1, w), lambda i, idx_ref: (idx_ref[i], 0)))
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, w), pool.dtype),
        # the pool operand (arg 2: after the scalar idx and blocks) is
        # donated into the output, so unwritten rows pass through
        input_output_aliases={2: 0},
        interpret=interpret)(idx, blocks, pool)
