"""Jit'd public wrappers for the Pallas kernels.

On this container everything executes in interpret mode (the kernel body
runs in Python on CPU — correctness path); on a real TPU `interpret=False`
compiles to Mosaic.  `on_tpu()` flips automatically.
"""
from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention_fwd
from .offload_quant import dequantize_blocked, quantize_blocked
from .ssd_scan import ssd_intra_chunk_fwd


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("causal", "sliding_window",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, sliding_window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    return flash_attention_fwd(q, k, v, causal=causal,
                               sliding_window=sliding_window,
                               block_q=block_q, block_k=block_k,
                               interpret=not on_tpu())


@jax.jit
def ssd_intra_chunk(xc, dtc, da, bc, cc):
    return ssd_intra_chunk_fwd(xc, dtc, da, bc, cc, interpret=not on_tpu())


def quantize_for_offload(x):
    return quantize_blocked(x, interpret=not on_tpu())


def dequantize_from_offload(q, s, meta):
    return dequantize_blocked(q, s, meta, interpret=not on_tpu())
