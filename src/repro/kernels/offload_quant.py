"""Compressed-swap kernels — beyond-paper optimization (DESIGN.md §2).

TENSILE's bottleneck is the host link: one transfer at a time at ~16 GB/s.
Quantizing swapped tensors to int8 with per-block scales halves (bf16) or
quarters (fp32) the bytes the channel must carry; the error affects only
the offloaded copy (activations destined for the backward pass tolerate
int8 well — gradient checkpointing literature routinely stores fp8/int8).

`quantize_blocked` / `dequantize_blocked` are Pallas kernels over row
blocks: per 1×BLOCK tile, scale = absmax/127, pack int8.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)               # (1, BLOCK)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (q * s_ref[...]).astype(x_ref.dtype)


def _to_2d(x):
    n = x.size
    pad = -n % BLOCK
    flat = jnp.pad(x.reshape(-1), (0, pad))
    return flat.reshape(-1, BLOCK), pad


def quantize_blocked(x, *, interpret: bool = True):
    """x: any shape/float dtype -> (q int8 (R,BLOCK), scales (R,1), meta)."""
    x2, pad = _to_2d(x)
    r = x2.shape[0]
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(r,),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((r, BLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((r, 1), jnp.float32)],
        interpret=interpret,
    )(x2)
    return q, s, (x.shape, str(x.dtype), pad)


def dequantize_blocked(q, s, meta, *, interpret: bool = True):
    shape, dtype, pad = meta
    r = q.shape[0]
    x2 = pl.pallas_call(
        _dequant_kernel,
        grid=(r,),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, BLOCK), jnp.dtype(dtype)),
        interpret=interpret,
    )(q, s)
    flat = x2.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compression_ratio(dtype) -> float:
    """Achieved swap-byte ratio vs the uncompressed tensor (incl. scales)."""
    itemsize = jnp.dtype(dtype).itemsize
    return (1.0 + 4.0 / BLOCK) / itemsize
