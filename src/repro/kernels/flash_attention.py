"""Flash attention forward — Pallas TPU kernel.

Tiled online-softmax attention with GQA: the (S×S) score tensor — the
dominant memory-peak tensor TENSILE would otherwise swap — is never
materialized; only (block_q × block_k) tiles live in VMEM.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks); the kv axis is the
innermost (sequential) dimension, carrying running max / denominator /
accumulator in VMEM scratch (the standard TPU flash pattern).  Blocks are
MXU-aligned (128) by default.  Causal blocks that are fully masked
contribute nothing (the `pl.when` guard skips their FLOPs on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, block_q: int, block_k: int,
                  num_kv_blocks: int, seq_len_q: int, seq_len_kv: int,
                  sliding_window: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip blocks that the causal mask voids entirely (saves their FLOPs)
    should_run = (k_start < q_start + block_q) if causal else (ki >= 0)

    @pl.when(should_run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(q * sm_scale, k,
                                (((1,), (1,)), ((), ())))  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len_kv
        if causal:
            mask &= kpos <= qpos
        if sliding_window:
            mask &= kpos > qpos - sliding_window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v)
        m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        o_ref[0, 0, ...] = (acc_scr[...]
                            / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        sliding_window: int = 0,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = True):
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H % KV == 0.
    Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    group = h // kvh
    sm_scale = 1.0 / np.sqrt(d)

    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(skv, 8))
    sq_pad = -sq % block_q
    skv_pad = -skv % block_k
    qt = jnp.moveaxis(q, 2, 1)                       # (B,H,Sq,D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if sq_pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_pad), (0, 0)))
    if skv_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, skv_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, skv_pad), (0, 0)))
    nq = (sq + sq_pad) // block_q
    nk = (skv + skv_pad) // block_k

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk, seq_len_q=sq, seq_len_kv=skv,
        sliding_window=sliding_window)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out[:, :, :sq], 1, 2)
