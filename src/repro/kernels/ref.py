"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        sliding_window: int = 0):
    """q: (B,Sq,H,D); k,v: (B,Skv,KV,D) -> (B,Sq,H,D); fp32 softmax."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    scale = 1.0 / np.sqrt(d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    skv = k.shape[1]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if sliding_window:
        mask &= kpos > qpos - sliding_window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def _segsum(x):
    s = jnp.cumsum(x, axis=-1)
    diff = s[..., :, None] - s[..., None, :]
    q = x.shape[-1]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_intra_chunk_ref(xc, dtc, da, bc, cc):
    """xc: (B,NC,Q,H,P); dtc/da: (B,NC,Q,H); bc/cc: (B,NC,Q,N)
    -> y_diag (B,NC,Q,H,P) fp32, states (B,NC,H,P,N) fp32."""
    xc32 = xc.astype(jnp.float32)
    da32 = da.astype(jnp.float32)
    dt32 = dtc.astype(jnp.float32)
    b32 = bc.astype(jnp.float32)
    c32 = cc.astype(jnp.float32)
    lmat = jnp.exp(_segsum(jnp.moveaxis(da32, 2, 3)))        # (B,NC,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", c32, b32)
    y = jnp.einsum("bcqk,bchqk,bckh,bckhp->bcqhp", scores, lmat, dt32, xc32)
    cum = jnp.cumsum(da32, axis=2)
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)
    states = jnp.einsum("bcqh,bcqh,bcqn,bcqhp->bchpn",
                        decay_end, dt32, b32, xc32)
    return y, states


def kv_block_gather_ref(pool, idx):
    """pool: (N,W); idx: (K,) int -> (K,W) rows of the pool."""
    return jnp.asarray(pool)[jnp.asarray(idx, jnp.int32)]


def kv_block_scatter_ref(pool, idx, blocks):
    """pool: (N,W); idx: (K,) int; blocks: (K,W) -> pool with ``idx`` rows
    replaced by ``blocks``; all other rows untouched."""
    pool = jnp.asarray(pool)
    return pool.at[jnp.asarray(idx, jnp.int32)].set(
        jnp.asarray(blocks, pool.dtype))


def quantize_blocked_ref(x, block: int = 512):
    flat = np.asarray(x, np.float32).reshape(-1)
    pad = -flat.size % block
    flat = np.pad(flat, (0, pad))
    x2 = flat.reshape(-1, block)
    amax = np.abs(x2).max(axis=-1, keepdims=True)
    scale = np.maximum(amax, 1e-12) / 127.0
    q = np.clip(np.round(x2 / scale), -127, 127).astype(np.int8)
    return q, scale.astype(np.float32), (x.shape, str(x.dtype), pad)


def dequantize_blocked_ref(q, s, meta):
    shape, dtype, pad = meta
    flat = (q.astype(np.float32) * s).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)
