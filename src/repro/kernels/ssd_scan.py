"""Mamba-2 SSD intra-chunk kernel — Pallas TPU.

Computes, per (batch, chunk, head) grid cell, the quadratic-dual intra-chunk
output and the chunk's contribution to the inter-chunk state:

    L[i,j]   = exp(sum_{j<k<=i} dA_k)          (lower-triangular decay)
    y_diag   = ((C Bᵀ) ⊙ L ⊙ dtᵀ) X            (Q,P)
    state    = Bᵀ ((exp(dA_total − cum(dA)) ⊙ dt) ⊙ X)   (N,P)

The (Q,Q) decay/score tiles live only in VMEM (Q = ssm_chunk, 256 default —
a 256×256 fp32 tile).  The cheap inter-chunk recurrence stays in jnp
(`models.ssm.ssd_chunked` consumes these outputs).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)        # (Q,P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)      # (Q,)
    da = da_ref[0, 0, :, 0].astype(jnp.float32)      # (Q,)
    bb = b_ref[0, 0].astype(jnp.float32)             # (Q,N)
    cc = c_ref[0, 0].astype(jnp.float32)             # (Q,N)

    cum = jnp.cumsum(da)                             # (Q,)
    diff = cum[:, None] - cum[None, :]               # (Q,Q)
    q = diff.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(ii >= jj, jnp.exp(diff), 0.0)   # (Q,Q)

    scores = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())))  # (Q,Q)
    w = scores * lmat * dt[None, :]
    y = jax.lax.dot(w, x)                            # (Q,P)
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(cum[-1] - cum) * dt          # (Q,)
    st = jax.lax.dot_general(bb * decay_end[:, None], x,
                             (((0,), (0,)), ((), ())))  # (N,P)
    st_ref[0, 0, 0] = st.astype(st_ref.dtype)


def ssd_intra_chunk_fwd(xc, dtc, da, bc, cc, *, interpret: bool = True):
    """xc: (B,NC,Q,H,P); dtc/da: (B,NC,Q,H); bc/cc: (B,NC,Q,N).

    Returns y_diag: (B,NC,Q,H,P), states: (B,NC,H,P,N) — matching the jnp
    reference in models.ssm / kernels.ref.
    """
    b, nc, q, h, p = xc.shape
    n = bc.shape[-1]
    kernel = _ssd_kernel

    y, st = pl.pallas_call(
        kernel,
        grid=(b, nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, 1, p),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, n, p),
                         lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dtc, da, bc, cc)
    # states stored (N,P) per head -> transpose to (P,N)
    return y, jnp.swapaxes(st, -1, -2)
